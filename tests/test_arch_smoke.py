"""Per-architecture smoke tests: REDUCED same-family configs run one
forward/train step and one cached-decode step on CPU (shape + finiteness
asserts).  Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.configs.base import ShapeConfig
from repro.models import api

ARCH_NAMES = sorted(R.ARCHS)


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = R.smoke_config(R.get_config(name))
            params = api.init_params(cfg, jax.random.key(0))
            cache[name] = (cfg, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_smoke(built, name):
    cfg, params = built(name)
    batch = api.synth_batch(cfg, R.SMOKE_SHAPE_TRAIN, jax.random.key(1))
    loss, aux = jax.jit(lambda p, b: api.loss_fn(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) < 20.0  # sane magnitude at init
    # gradients flow and are finite
    g, _ = jax.grad(lambda p: api.loss_fn(p, cfg, batch),
                    has_aux=True)(params)
    flat = jax.tree.leaves(g)
    assert all(np.isfinite(np.asarray(x, np.float32)).all() for x in flat)
    assert any(float(jnp.abs(x).max()) > 0 for x in flat)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_step_smoke(built, name):
    cfg, params = built(name)
    out = api.synth_batch(cfg, R.SMOKE_SHAPE_DECODE, jax.random.key(2))
    batch, caches = out
    logits, new_caches = jax.jit(
        lambda b, c: api.decode_step(params, cfg, b, c))(batch, caches)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # cache structure preserved
    assert jax.tree.structure(caches) == jax.tree.structure(new_caches)


@pytest.mark.parametrize("name", ["llama3.2-1b", "gemma3-12b",
                                  "zamba2-2.7b", "xlstm-125m",
                                  "moonshot-v1-16b-a3b", "whisper-tiny",
                                  "gemma-2b", "qwen1.5-110b",
                                  "granite-moe-3b-a800m"])
def test_decode_matches_teacher_forcing(built, name):
    """Cached decode == full forward, step by step (catches rope/cache/mask
    bugs and validates chunked-SSD vs recurrence)."""
    cfg, params = built(name)
    T = 16
    batch = api.synth_batch(cfg, ShapeConfig("t", T, 2, "train"),
                            jax.random.key(1))
    logits_tf = api.prefill_step(params, cfg, batch)
    caches = api.make_caches(cfg, 2, T, jnp.float32)
    if api.is_encdec(cfg):
        from repro.models import encdec as ED
        extra = {"enc_states": ED.encode(params, cfg, batch["frames"])}
    else:
        extra = {}
    dec = jax.jit(lambda b, c: api.decode_step(params, cfg, b, c))
    toks = batch["tokens"]
    worst = 0.0
    for t in range(T):
        lg, caches = dec({"token": toks[:, t:t + 1], **extra}, caches)
        worst = max(worst, float(np.abs(
            np.asarray(lg[:, 0], np.float32)
            - np.asarray(logits_tf[:, t], np.float32)).max()))
    assert worst < 5e-4, worst


def test_moe_conservation():
    """Every routed (non-dropped) token contributes normalized gate mass."""
    from repro.models.moe import moe_apply, moe_init
    cfg = R.smoke_config(R.get_config("moonshot-v1-16b-a3b"))
    p = moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
    y, aux = moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert int(aux["expert_load"].sum()) + int(aux["dropped"]) == \
        2 * 32 * cfg.moe_top_k
    assert float(aux["aux_loss"]) > 0.0


def test_all_archs_registered():
    assert len(R.ARCHS) == 10
    fams = {c.family for c in R.ARCHS.values()}
    assert fams == {"hybrid", "dense", "ssm", "moe", "audio", "vlm"}
