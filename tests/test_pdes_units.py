"""Unit + property tests for PDES primitives (buffers, routing, pools, rng).

Shapes are FIXED inside each test so jax's jit cache is hit across
hypothesis examples (content varies, compilation does not).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import events as ev
from repro.core import rng
from repro.core.buffering import append, route_records
from repro.core.types import Staged, TIME_MAX

SET = dict(max_examples=25, deadline=None)

N = 64  # record count used across tests (fixed -> one compile)
S = 4   # shards


# ---------------------------------------------------------------------------
# append
# ---------------------------------------------------------------------------
@settings(**SET)
@given(st.lists(st.booleans(), min_size=N, max_size=N),
       st.integers(min_value=0, max_value=20))
def test_append_counts_and_contents(mask, count0):
    cap = 48
    buf = dict(x=jnp.zeros((cap,), jnp.int32))
    vals = jnp.arange(N, dtype=jnp.int32) + 100
    new = dict(x=vals)
    valid = jnp.asarray(mask)
    buf["x"] = buf["x"].at[:count0].set(-1)
    out, count, dropped = jax.jit(append, static_argnums=4)(
        buf, jnp.int32(count0), new, valid, cap)
    n_live = int(np.sum(mask))
    want_added = min(n_live, cap - count0)
    assert int(count) == count0 + want_added
    assert int(dropped) == n_live - want_added
    got = set(np.asarray(out["x"][count0:int(count)]).tolist())
    want = set((np.asarray(vals)[np.asarray(mask)])[:want_added].tolist())
    assert got == want


# ---------------------------------------------------------------------------
# route_records (vmap harness)
# ---------------------------------------------------------------------------
def _route(fields, dest, valid, per_dest_cap):
    f = jax.vmap(
        lambda fl, d, v: route_records(fl, d, v, S, per_dest_cap, "i"),
        axis_name="i")
    return f(fields, dest, valid)


@settings(**SET)
@given(st.lists(st.integers(min_value=0, max_value=S - 1),
                min_size=S * N, max_size=S * N),
       st.lists(st.booleans(), min_size=S * N, max_size=S * N))
def test_route_records_delivers_exactly_once(dests, valids):
    dest = jnp.asarray(dests, jnp.int32).reshape(S, N)
    valid = jnp.asarray(valids).reshape(S, N)
    payload = (jnp.arange(S * N, dtype=jnp.int32)).reshape(S, N)
    fields = dict(p=payload)
    recv, rvalid, n_sent, n_dropped = _route(fields, dest, valid, N)
    # capacity N per dest is ample (max N live per source shard)
    assert int(n_dropped.sum()) == 0
    sent = set(np.asarray(payload)[np.asarray(valid)].tolist())
    got = set(np.asarray(recv["p"])[np.asarray(rvalid)].tolist())
    assert sent == got
    # every record landed on the shard it addressed
    dest_np, val_np = np.asarray(dest), np.asarray(valid)
    recv_np, rv_np = np.asarray(recv["p"]), np.asarray(rvalid)
    for sh in range(S):
        want = set(np.asarray(payload)[(dest_np == sh) & val_np].tolist())
        assert set(recv_np[sh][rv_np[sh]].tolist()) == want


def test_route_records_overflow_counted():
    dest = jnp.zeros((S, N), jnp.int32)  # everyone targets shard 0
    valid = jnp.ones((S, N), bool)
    fields = dict(p=jnp.arange(S * N, dtype=jnp.int32).reshape(S, N))
    cap = 8
    recv, rvalid, n_sent, n_dropped = _route(fields, dest, valid, cap)
    assert int(n_sent.sum()) == S * cap
    assert int(n_dropped.sum()) == S * (N - cap)


# ---------------------------------------------------------------------------
# event pool
# ---------------------------------------------------------------------------
@settings(**SET)
@given(st.lists(st.booleans(), min_size=N, max_size=N))
def test_pool_insert_then_drain(mask):
    cap = 128
    pool = ev.empty_pool(cap)
    staged = Staged(
        time=jnp.arange(N, dtype=jnp.int32),
        kind=jnp.zeros((N,), jnp.int32),
        dst=jnp.zeros((N,), jnp.int32),
        a0=jnp.arange(N, dtype=jnp.int32),
        a1=jnp.zeros((N,), jnp.int32),
        a2=jnp.zeros((N,), jnp.int32),
        valid=jnp.asarray(mask),
    )
    pool, dropped = jax.jit(ev.insert)(pool, staged)
    n = int(np.sum(mask))
    assert int(dropped) == 0
    assert int(ev.occupancy(pool)) == n
    if n:
        first = int(np.min(np.arange(N)[np.asarray(mask)]))
        assert int(ev.next_time(pool)) == first
    pool = ev.invalidate(pool, pool.valid)
    assert int(ev.occupancy(pool)) == 0
    assert int(ev.next_time(pool)) == int(TIME_MAX)


def test_pool_overflow_is_counted():
    pool = ev.empty_pool(16)
    staged = Staged(
        time=jnp.arange(32, dtype=jnp.int32),
        kind=jnp.zeros((32,), jnp.int32), dst=jnp.zeros((32,), jnp.int32),
        a0=jnp.zeros((32,), jnp.int32), a1=jnp.zeros((32,), jnp.int32),
        a2=jnp.zeros((32,), jnp.int32), valid=jnp.ones((32,), bool))
    pool, dropped = ev.insert(pool, staged)
    assert int(dropped) == 16
    assert int(ev.occupancy(pool)) == 16


# ---------------------------------------------------------------------------
# rng
# ---------------------------------------------------------------------------
def test_mix32_uniformity_and_determinism():
    x = jnp.arange(1 << 14, dtype=jnp.uint32)
    bits = rng.rand_bit(x, rng.SALT_BIT)
    assert abs(float(bits.mean()) - 0.5) < 0.02
    u = rng.uniform01(x, rng.SALT_LOSS)
    assert 0.0 <= float(u.min()) and float(u.max()) < 1.0
    assert abs(float(u.mean()) - 0.5) < 0.02
    again = rng.rand_bit(x, rng.SALT_BIT)
    assert (np.asarray(bits) == np.asarray(again)).all()


def test_salts_decorrelated():
    x = jnp.arange(1 << 14, dtype=jnp.uint32)
    a = rng.rand_bit(x, rng.SALT_BIT)
    b = rng.rand_bit(x, rng.SALT_TX_BASIS)
    agree = float((a == b).mean())
    assert abs(agree - 0.5) < 0.03
