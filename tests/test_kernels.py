"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.types import TIME_MAX
from repro.kernels.event_select.kernel import event_select
from repro.kernels.event_select.ref import event_select_ref
from repro.kernels.flash_attention.kernel import flash_attention_padded
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.qchannel.kernel import qchannel_2d
from repro.kernels.qchannel.ops import transmit_measure
from repro.kernels.qchannel.ref import qchannel_ref


# ---------------------------------------------------------------------------
# qchannel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("rows", [8, 64, 520])
def test_qchannel_kernel_matches_ref(rows):
    key = jax.random.key(0)
    uid = jax.random.bits(key, (rows, 128), dtype=jnp.uint32)
    loss = jax.random.uniform(jax.random.key(1), (rows, 128),
                              jnp.float32, 0.0, 0.5)
    bit = jax.random.bernoulli(jax.random.key(2),
                               shape=(rows, 128)).astype(jnp.int32)
    basis = jax.random.bernoulli(jax.random.key(3),
                                 shape=(rows, 128)).astype(jnp.int32)
    got = qchannel_2d(uid, loss, bit, basis, interpret=True)
    want = qchannel_ref(uid.reshape(-1), loss.reshape(-1),
                        bit.reshape(-1), basis.reshape(-1))
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g).reshape(-1),
                                      np.asarray(w))


@pytest.mark.parametrize("n", [1, 100, 128, 1000, 4096])
def test_qchannel_ops_flat_padding(n):
    uid = jnp.arange(n, dtype=jnp.uint32) * 7
    loss = jnp.full((n,), 0.25, jnp.float32)
    bit = (uid % 2).astype(jnp.int32)
    basis = ((uid >> 1) % 2).astype(jnp.int32)
    got = transmit_measure(uid, loss, bit, basis, use_kernel=True,
                           interpret=True)
    want = transmit_measure(uid, loss, bit, basis, use_kernel=False)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_qchannel_physics():
    n = 1 << 14
    uid = jnp.arange(n, dtype=jnp.uint32)
    loss = jnp.full((n,), 0.3, jnp.float32)
    bit = jnp.zeros((n,), jnp.int32)
    basis = jnp.zeros((n,), jnp.int32)
    det, rx, out = transmit_measure(uid, loss, bit, basis, use_kernel=False)
    assert abs(float(det.mean()) - 0.7) < 0.02
    match = rx == basis
    # matched basis -> exact bit; mismatched -> ~50/50
    np.testing.assert_array_equal(np.asarray(out[match]), 0)
    assert abs(float(out[~match].mean()) - 0.5) < 0.05


# ---------------------------------------------------------------------------
# event_select
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cap", [128, 512, 2048])
@pytest.mark.parametrize("seed", [0, 1])
def test_event_select_matches_ref(cap, seed):
    k = jax.random.key(seed)
    time = jax.random.randint(k, (cap,), 0, 1000, jnp.int32)
    valid = jax.random.bernoulli(jax.random.key(seed + 10), 0.7, (cap,))
    end = jnp.int32(500)
    got_o, got_c = event_select(time, valid, end, interpret=True)
    want_o, want_c = event_select_ref(time, valid, end)
    assert int(got_c) == int(want_c)
    np.testing.assert_array_equal(np.asarray(got_o), np.asarray(want_o))


def test_event_select_empty_and_full():
    cap = 256
    time = jnp.arange(cap, dtype=jnp.int32)
    none_valid = jnp.zeros((cap,), bool)
    o, c = event_select(time, none_valid, jnp.int32(1000), interpret=True)
    assert int(c) == 0
    all_valid = jnp.ones((cap,), bool)
    o, c = event_select(time, all_valid, jnp.int32(TIME_MAX),
                        interpret=True)
    assert int(c) == cap
    np.testing.assert_array_equal(np.asarray(o), np.arange(cap))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,S,H,Hkv,D", [
    (128, 128, 4, 4, 128),     # MHA square
    (256, 256, 8, 2, 128),     # GQA
    (128, 384, 4, 1, 128),     # MQA, cross lengths
])
def test_flash_attention_matches_ref(T, S, H, Hkv, D, dtype):
    B = 2
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, H, T, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), dtype)
    got = flash_attention_padded(q, k, v, sm_scale=D ** -0.5, causal=True,
                                 window=None, q_len=T, kv_len=S,
                                 interpret=True)
    want = attention_ref(q, k, v, sm_scale=D ** -0.5, causal=True)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, jnp.float32),
                               np.asarray(want, jnp.float32), atol=atol)


@pytest.mark.parametrize("window", [64, 128])
def test_flash_attention_sliding_window(window):
    B, H, T, D = 1, 2, 256, 128
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (B, H, T, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, T, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, T, D), jnp.float32)
    got = flash_attention_padded(q, k, v, sm_scale=D ** -0.5, causal=True,
                                 window=window, q_len=T, kv_len=T,
                                 interpret=True)
    want = attention_ref(q, k, v, sm_scale=D ** -0.5, causal=True,
                         window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_ragged_padding():
    """ops wrapper: non-multiple seq lengths via padding + masking."""
    B, H, T, S, D = 1, 2, 100, 203, 128
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (B, H, T, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, S, D), jnp.float32)
    got = flash_attention(q, k, v, causal=False, use_kernel=True,
                          interpret=True)
    want = attention_ref(q, k, v, sm_scale=D ** -0.5, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
