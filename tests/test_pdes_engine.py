"""End-to-end behaviour tests for the PDES engine (the paper's system).

The headline property: simulation results are BIT-IDENTICAL for any shard
count, any partitioning scheme, and either QSM design — the serial-
equivalence guarantee of a conservative PDES.
"""
import numpy as np
import pytest

from repro.core import (
    EngineConfig, Simulator, as_network, cut_channels, linear_network,
    make_partition,
)


def small_cfg(S, **kw):
    base = dict(n_shards=S, pool_cap=2048, qsm_cap=1024, outbox_cap=1024,
                route_cap=256)
    base.update(kw)
    return EngineConfig(**base)


def run(net, S, scheme="contiguous", qsm_mode="gathered", **runkw):
    part = make_partition(net, S, scheme=scheme)
    sim = Simulator(net, part, small_cfg(S, qsm_mode=qsm_mode))
    return sim.run(max_epochs=10_000, chunk=32, **runkw)


@pytest.fixture(scope="module")
def linear_net():
    return linear_network(n_routers=8, n_photons=24, period_ns=1_000,
                          hop_delay_ns=25_000, loss_p=0.1)


@pytest.fixture(scope="module")
def as_net():
    return as_network(n_routers=32, n_as=4, n_photons=24, seed=3)


# ---------------------------------------------------------------------------
# serial equivalence
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("S", [2, 4])
@pytest.mark.parametrize("qsm_mode", ["gathered", "hashed"])
def test_shard_count_invariance_linear(linear_net, S, qsm_mode):
    ref = run(linear_net, 1)
    got = run(linear_net, S, qsm_mode=qsm_mode)
    assert ref.fingerprint() == got.fingerprint()
    assert got.overflow == 0 and got.stale_reads == 0


@pytest.mark.parametrize("scheme", ["contiguous", "random", "sa"])
def test_partition_invariance_as(as_net, scheme):
    ref = run(as_net, 1)
    got = run(as_net, 4, scheme=scheme)
    assert ref.fingerprint() == got.fingerprint()


# ---------------------------------------------------------------------------
# BB84 physics
# ---------------------------------------------------------------------------
def test_noiseless_qber_is_zero(linear_net):
    r = run(linear_net, 2)
    assert r.errors.sum() == 0
    assert r.qber == 0.0


def test_all_photons_emitted(linear_net):
    r = run(linear_net, 2)
    want = sum(s.n_photons for s in linear_net.sessions)
    assert int(r.emitted.sum()) == want


def test_loss_statistics():
    net = linear_network(n_routers=4, n_photons=400, loss_p=0.3)
    r = run(net, 2)
    rate = r.detected.sum() / r.emitted.sum()
    assert abs(rate - 0.7) < 0.05


def test_sift_rate_near_half():
    net = linear_network(n_routers=4, n_photons=400, loss_p=0.0)
    r = run(net, 2)
    rate = r.sifted.sum() / r.detected.sum()
    assert abs(rate - 0.5) < 0.06


def test_keys_nonempty_every_session(linear_net):
    r = run(linear_net, 4)
    assert (r.sifted > 0).all()


# ---------------------------------------------------------------------------
# work stealing (paper §IV proposal)
# ---------------------------------------------------------------------------
def test_work_stealing_is_exact_and_helps(as_net):
    base = run(as_net, 4, scheme="sa")
    steal = run(as_net, 4, scheme="sa", steal_every=1, steal_threshold=1.05)
    assert base.fingerprint() == steal.fingerprint()
    ev_b = np.asarray(base.metrics.events_by_kind).sum(-1).sum(1)
    ev_s = np.asarray(steal.metrics.events_by_kind).sum(-1).sum(1)
    if steal.steals:  # if any moves happened, imbalance must not worsen
        assert ev_s.max() <= ev_b.max()


def test_burst_emission_exact_and_fewer_waves(as_net):
    """§Perf iteration 3 (PDES): burst emission is bit-identical and
    collapses the EMIT-chain wave depth."""
    part = make_partition(as_net, 4, scheme="sa")
    base = small_cfg(4)
    r0 = Simulator(as_net, part, base).run(max_epochs=10_000, chunk=32)
    r1 = Simulator(as_net, part,
                   small_cfg(4, burst_emit=True)).run(max_epochs=10_000,
                                                      chunk=32)
    assert r0.fingerprint() == r1.fingerprint()
    w0 = int(np.asarray(r0.metrics.n_waves).sum())
    w1 = int(np.asarray(r1.metrics.n_waves).sum())
    assert w1 < w0


# ---------------------------------------------------------------------------
# partitioner
# ---------------------------------------------------------------------------
def test_sa_beats_random_cut(as_net):
    sa = cut_channels(as_net, make_partition(as_net, 8, "sa"))
    rnd = cut_channels(as_net, make_partition(as_net, 8, "random"))
    assert sa <= rnd


def test_linear_contiguous_cut_is_minimal(linear_net):
    part = make_partition(linear_net, 4, "contiguous")
    assert cut_channels(linear_net, part) == 3  # S-1 cut edges


# ---------------------------------------------------------------------------
# instrumentation sanity
# ---------------------------------------------------------------------------
def test_metrics_account_for_all_events(linear_net):
    r = run(linear_net, 2)
    total_emit = int(np.asarray(r.metrics.events_by_kind)[..., 0].sum())
    assert total_emit == int(r.emitted.sum())


def test_epoch_end_monotonic(linear_net):
    r = run(linear_net, 2)
    ee = np.asarray(r.metrics.epoch_end)  # (S, E)
    live = ee < np.iinfo(np.int32).max // 2
    for srow, lrow in zip(ee, live):
        seq = srow[lrow]
        assert (np.diff(seq) >= 0).all()
