"""Runtime tests: training loop, checkpoint/restart fault tolerance,
deterministic resume, serving, elastic re-mesh planning, optimizer."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer as CK
from repro.configs import registry as R
from repro.data.synthetic import DataConfig, make_batch, make_shard_batch
from repro.optim import adamw
from repro.optim.compress import apply as compress_apply, init_residual
from repro.runtime.elastic import (
    ElasticController, HeartbeatMonitor, plan_remesh,
)
from repro.runtime.trainer import TrainConfig, Trainer


def tiny_cfg():
    cfg = R.smoke_config(R.get_config("llama3.2-1b"))
    return dataclasses.replace(cfg, n_layers=2, d_model=64, n_heads=2,
                               n_kv_heads=2, head_dim=32, d_ff=128,
                               vocab_size=128)


# ---------------------------------------------------------------------------
# training loop
# ---------------------------------------------------------------------------
def test_loss_decreases():
    tc = TrainConfig(arch=tiny_cfg(), steps=30, lr=3e-3, seq_len=64,
                     global_batch=4)
    tr = Trainer(tc)
    summary = tr.train()
    losses = [r.loss for r in tr.timer.records]
    assert summary["final_loss"] < losses[0] - 0.3, (losses[0],
                                                     summary["final_loss"])


def test_checkpoint_resume_is_bit_deterministic(tmp_path):
    """THE fault-tolerance test: crash after step 6, resume, and the loss
    trajectory must be IDENTICAL to an uninterrupted run."""
    arch = tiny_cfg()
    base = dict(arch=arch, lr=3e-3, seq_len=64, global_batch=4,
                ckpt_every=3)

    tc_a = TrainConfig(steps=12, ckpt_dir=str(tmp_path / "a"), **base)
    tr_a = Trainer(tc_a)
    tr_a.train()
    losses_a = [r.loss for r in tr_a.timer.records]

    tc_b = TrainConfig(steps=6, ckpt_dir=str(tmp_path / "b"), **base)
    tr_b1 = Trainer(tc_b)
    tr_b1.train()
    del tr_b1  # "crash"
    tr_b2 = Trainer(dataclasses.replace(tc_b, steps=6))  # resumes at 6
    assert tr_b2.step == 6
    tr_b2.train(6)
    losses_b2 = [r.loss for r in tr_b2.timer.records]
    np.testing.assert_allclose(losses_a[6:], losses_b2, rtol=1e-6)


def test_checkpoint_atomicity(tmp_path):
    tree = {"a": jnp.arange(10), "b": {"c": jnp.ones((3, 3))}}
    CK.save(tmp_path, 5, tree, extra={"step": 5})
    CK.save(tmp_path, 10, tree, extra={"step": 10})
    assert CK.latest_step(tmp_path) == 10
    like = jax.tree.map(jnp.zeros_like, tree)
    got, extra = CK.restore(tmp_path, 10, like)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.arange(10))
    assert extra["step"] == 10


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    CK.save(tmp_path, 1, {"w": jnp.ones((4,))})
    with pytest.raises(ValueError):
        CK.restore(tmp_path, 1, {"w": jnp.ones((5,))})


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_data_deterministic_and_disjoint():
    dc = DataConfig(vocab_size=97, seq_len=32, global_batch=8, seed=1)
    b1 = make_batch(dc, 7)
    b2 = make_batch(dc, 7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = make_batch(dc, 8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    s0 = make_shard_batch(dc, 3, 0, 4)["tokens"]
    s1 = make_shard_batch(dc, 3, 1, 4)["tokens"]
    assert not np.array_equal(s0, s1)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def test_decode_server_continuous_batching():
    from repro.runtime.server import DecodeServer, Request
    from repro.models import api

    cfg = tiny_cfg()
    params = api.init_params(cfg, jax.random.key(0))
    srv = DecodeServer(cfg, params, slots=2, max_seq=64)
    for rid in range(5):  # more requests than slots
        srv.submit(Request(rid=rid, prompt=[1, 2, 3 + rid],
                           max_new_tokens=4))
    done = srv.run()
    assert len(done) == 5
    assert all(len(r.output) == 4 for r in done)
    # greedy decode is deterministic given the same prompt
    srv2 = DecodeServer(cfg, params, slots=2, max_seq=64)
    srv2.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=4))
    out2 = srv2.run()[0].output
    first = next(r for r in done if r.rid == 0)
    assert first.output == out2


# ---------------------------------------------------------------------------
# elastic
# ---------------------------------------------------------------------------
def test_heartbeat_and_remesh_plan():
    mon = HeartbeatMonitor(n_hosts=8, timeout_s=10.0)
    now = 1000.0
    for h in range(8):
        mon.beat(h, when=now)
    assert mon.sweep(now + 5) == []
    mon.beat(3, when=now)  # host 3 goes silent
    for h in (0, 1, 2, 4, 5, 6, 7):
        mon.beat(h, when=now + 20)
    assert mon.sweep(now + 20) == [3]
    # 7 alive hosts, 1 host per TP group, old data axis 8 -> shrink to 4
    assert plan_remesh(7, 1, 8) == 4
    with pytest.raises(RuntimeError):
        plan_remesh(1, 2, 8)


def test_elastic_controller_triggers_rebuild():
    mon = HeartbeatMonitor(n_hosts=4, timeout_s=1.0)
    now = 0.0
    for h in range(4):
        mon.beat(h, when=now)
    ctl = ElasticController(mon, hosts_per_tp_group=1, data_axis=4)
    rebuilt = {}

    def rebuild(new_data):
        rebuilt["data"] = new_data
        return 42  # restored step

    for h in (0, 1, 2):
        mon.beat(h, when=now + 5)
    ev = ctl.check(rebuild, now=now + 5)
    assert ev is not None and ev.new_data == 2 and ev.restored_step == 42
    assert rebuilt["data"] == 2


# ---------------------------------------------------------------------------
# optimizer + compression
# ---------------------------------------------------------------------------
def test_adamw_matches_reference_math():
    params = {"w": jnp.array([1.0, -2.0, 3.0])}
    grads = {"w": jnp.array([0.1, 0.2, -0.3])}
    st = adamw.init(params)
    new_p, st2, gnorm = adamw.update(grads, st, params, lr=0.1, b1=0.9,
                                     b2=0.999, eps=1e-8, weight_decay=0.0,
                                     grad_clip=1e9)
    g = np.array([0.1, 0.2, -0.3])
    mu = 0.1 * g
    nu = 0.001 * g * g
    mhat = mu / (1 - 0.9)
    vhat = nu / (1 - 0.999)
    want = np.array([1.0, -2.0, 3.0]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)
    assert int(st2.count) == 1


def test_grad_compression_error_feedback():
    g = {"w": jnp.array(np.random.default_rng(0).normal(size=512),
                        jnp.float32)}
    r = init_residual(g)
    total_deq = np.zeros(512)
    total_g = np.zeros(512)
    for _ in range(50):  # same grad repeatedly: EF must converge on average
        deq, r = compress_apply(g, r)
        total_deq += np.asarray(deq["w"])
        total_g += np.asarray(g["w"])
    np.testing.assert_allclose(total_deq / 50, total_g / 50, atol=1e-3)
