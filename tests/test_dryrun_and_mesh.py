"""Subprocess tests: (a) the real dry-run CLI on one cell; (b) PDES
vmap-vs-shard_map equivalence on a 4-device host mesh.

Run in subprocesses because they need XLA_FLAGS device-count settings that
must precede jax initialization (pytest's process has 1 device)."""
import json
import os
import subprocess
import sys
import tempfile
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
ENV = dict(os.environ, PYTHONPATH=str(REPO / "src"))


@pytest.mark.slow
def test_dryrun_cli_one_cell(tmp_path):
    """lower+compile on the REAL 512-device production mesh."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "whisper-tiny", "--shape", "decode_32k",
         "--mesh", "single", "--out", str(tmp_path)],
        env=ENV, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads(
        (tmp_path /
         "whisper-tiny__decode_32k__single_pod_16x16.json").read_text())
    assert rec["status"] == "ok"
    assert rec["n_devices"] == 256
    assert rec["roofline"]["compute_s"] > 0


MESH_EQUIV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
from repro.core import EngineConfig, Simulator, linear_network, \
    make_partition
from repro.launch.mesh import make_pdes_mesh

net = linear_network(n_routers=16, n_photons=16)
part = make_partition(net, 4, scheme="contiguous")
cfg = EngineConfig(n_shards=4, pool_cap=1024, qsm_cap=512,
                   outbox_cap=512, route_cap=128)
r_vmap = Simulator(net, part, cfg).run()
mesh = make_pdes_mesh(4)
r_mesh = Simulator(net, part, cfg, mesh=mesh).run()
assert r_vmap.overflow == 0 and r_mesh.overflow == 0
assert r_vmap.fingerprint() == r_mesh.fingerprint(), (
    hex(r_vmap.fingerprint()), hex(r_mesh.fingerprint()))
print("MESH_EQUIV_OK", hex(r_mesh.fingerprint()))
"""


@pytest.mark.slow
def test_pdes_vmap_shardmap_equivalence():
    """The emulation path (vmap) and the real mesh path (shard_map) must be
    bit-identical — proves the dry-run artifact computes the same sim."""
    r = subprocess.run([sys.executable, "-c", MESH_EQUIV_SCRIPT],
                       env=ENV, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "MESH_EQUIV_OK" in r.stdout
