"""Roofline report: assemble the §Roofline table from dry-run JSONs."""
import json
import sys
from pathlib import Path

DRYRUN = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def load(mesh_filter="single_pod_16x16"):
    rows = []
    for p in sorted(DRYRUN.glob("*.json")):
        d = json.loads(p.read_text())
        if d.get("mesh") != mesh_filter:
            continue
        rows.append(d)
    return rows


def main():
    rows = load()
    print("# roofline: per (arch x shape), single-pod 16x16, v5e terms (s)")
    print("arch,shape,status,compute_s,memory_s,collective_s,dominant,"
          "useful_flops_ratio,args_bytes_per_dev")
    for d in rows:
        if d["status"] != "ok":
            print(f"{d['arch']},{d['shape']},{d['status']},,,,,,")
            continue
        r = d["roofline"]
        ratio = (d["model_flops"] /
                 (r["flops_per_dev"] * d["n_devices"])
                 if r["flops_per_dev"] else 0.0)
        mem = d.get("memory_analysis") or {}
        print(f"{d['arch']},{d['shape']},ok,{r['compute_s']:.3e},"
              f"{r['memory_s']:.3e},{r['collective_s']:.3e},"
              f"{r['dominant']},{ratio:.2f},"
              f"{mem.get('argument_size_in_bytes', '')}")


if __name__ == "__main__":
    main()
