"""Fig 3 reproduction: linear-topology strong scaling, 1-128 processes.

Paper methodology: average per-process time split into compute / socket
(global QSM) / MPI — where "MPI" lumps straggler wait together with
communication (the lumping Fig 5 later unpicks).
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent))
from pdes_common import engine_breakdown, paper_breakdown, run_sim  # noqa

SCALES = [1, 2, 4, 8, 16, 32, 64, 128]


def rows():
    out = []
    base_total = None
    for S in SCALES:
        d = run_sim("linear", S)
        bd = paper_breakdown(d)
        av = bd.averages()
        mpi = av["wait"] + av["comm"]          # the paper's original lumping
        total = bd.total_wall
        if base_total is None:
            base_total = total
        ebd = engine_breakdown(d)
        out.append(dict(
            S=S, compute_s=av["compute"], socket_s=av["qsm"], mpi_s=mpi,
            total_s=total, speedup=base_total / total,
            engine_total_s=ebd.total_wall,
            events=int(d["events_by_kind"].sum()),
            epochs=d["n_epochs"]))
    return out


def main():
    print("# fig3_linear: projected SeQUeNCe-like (FRONTIER+SEQUENCE_PY); "
          "engine_total = this engine (TPU_POD+vector model)")
    print("S,compute_s,socket_s,mpi_s,total_s,speedup,engine_total_s,"
          "events,epochs")
    for r in rows():
        print(f"{r['S']},{r['compute_s']:.4f},{r['socket_s']:.4f},"
              f"{r['mpi_s']:.4f},{r['total_s']:.4f},{r['speedup']:.2f},"
              f"{r['engine_total_s']:.5f},{r['events']},{r['epochs']}")


if __name__ == "__main__":
    main()
