"""Fig 6 reproduction: wait time counted as compute ("we argue that time
spent waiting on other processes should be included in determining overall
compute time") — the honest view of the scalability limit."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent))
from pdes_common import paper_breakdown, run_sim  # noqa

SCALES = [1, 2, 4, 8, 16, 32, 64, 128, 256]  # S=512: single-core host budget, see EXPERIMENTS.md


def rows():
    out = []
    for S in SCALES:
        d = run_sim("as", S)
        av = paper_breakdown(d, merge_wait=True).averages()
        out.append(dict(S=S, compute_incl_wait_s=av["compute"],
                        comm_s=av["comm"], socket_s=av["qsm"]))
    return out


def main():
    print("# fig6_redefined: compute includes straggler wait, AS topology")
    print("S,compute_incl_wait_s,comm_s,socket_s")
    for r in rows():
        print(f"{r['S']},{r['compute_incl_wait_s']:.4f},"
              f"{r['comm_s']:.6f},{r['socket_s']:.4f}")


if __name__ == "__main__":
    main()
