"""Beyond-paper: the paper §IV proposal, built — hash-distributed QSM vs
the single gathered server.  Same simulations, identical results
(fingerprints equal), different cost structure."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent))
from pdes_common import paper_breakdown, run_sim  # noqa

SCALES = [4, 16, 64]


def rows():
    out = []
    for S in SCALES:
        g = run_sim("as", S, mode="gathered")
        h = run_sim("as", S, mode="hashed")
        assert g["fingerprint"] == h["fingerprint"], "QSM modes diverge!"
        bg, bh = paper_breakdown(g), paper_breakdown(h)
        out.append(dict(
            S=S,
            gathered_qsm_s=bg.averages()["qsm"],
            hashed_qsm_s=bh.averages()["qsm"],
            gathered_total_s=bg.total_wall,
            hashed_total_s=bh.total_wall,
            qsm_speedup=(bg.averages()["qsm"] /
                         max(bh.averages()["qsm"], 1e-12)),
            requests=int(g["qsm_requests"].sum())))
    return out


def main():
    print("# beyond_qsm: gathered (paper-faithful single server) vs hashed "
          "(distributed ownership); identical results verified")
    print("S,gathered_qsm_s,hashed_qsm_s,qsm_speedup,gathered_total_s,"
          "hashed_total_s,requests")
    for r in rows():
        print(f"{r['S']},{r['gathered_qsm_s']:.4f},{r['hashed_qsm_s']:.4f},"
              f"{r['qsm_speedup']:.1f},{r['gathered_total_s']:.4f},"
              f"{r['hashed_total_s']:.4f},{r['requests']}")


if __name__ == "__main__":
    main()
