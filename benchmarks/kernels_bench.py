"""Kernel microbenchmarks (CPU wall time; the Pallas kernels additionally
run in interpret mode for a correctness-throughput sanity number)."""
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp

sys.path.insert(0, str(Path(__file__).parent))


def _bench(fn, *args, iters=20):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def rows():
    out = []
    # qchannel: 64k photons
    from repro.kernels.qchannel.ref import qchannel_ref
    n = 1 << 16
    uid = jnp.arange(n, dtype=jnp.uint32)
    loss = jnp.full((n,), 0.2, jnp.float32)
    bit = (uid & 1).astype(jnp.int32)
    basis = ((uid >> 1) & 1).astype(jnp.int32)
    us = _bench(qchannel_ref, uid, loss, bit, basis)
    out.append(("qchannel_ref_64k", us, f"{n / us:.0f}Mphotons/s".replace(
        "M", "" if us > 1e6 else "M")))

    # event_select: 8k pool
    from repro.kernels.event_select.ref import event_select_ref
    cap = 8192
    t = jax.random.randint(jax.random.key(0), (cap,), 0, 10_000, jnp.int32)
    v = jax.random.bernoulli(jax.random.key(1), 0.7, (cap,))
    us = _bench(event_select_ref, t, v, jnp.int32(5000))
    out.append(("event_select_ref_8k", us, f"{cap / us:.1f}events/us"))

    # flash-equivalent chunked attention vs dense oracle, T=2048
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.models.chunked_attention import chunked_attention
    import functools
    B, H, T, D = 1, 8, 2048, 64
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (B, H, T, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, T, D), jnp.float32)
    v3 = jax.random.normal(ks[2], (B, H, T, D), jnp.float32)
    dense = jax.jit(functools.partial(attention_ref, sm_scale=D ** -0.5,
                                      causal=True))
    chunked = jax.jit(functools.partial(chunked_attention, causal=True,
                                        sm_scale=D ** -0.5, chunk=512))
    us_d = _bench(dense, q, k, v3, iters=5)
    us_c = _bench(chunked, q, k, v3, iters=5)
    out.append(("attention_dense_2k", us_d, "oracle"))
    out.append(("attention_chunked_2k", us_c,
                f"{us_d / us_c:.2f}x_vs_dense"))

    # PDES engine throughput (measured on this host)
    from repro.core import EngineConfig, Simulator, linear_network, \
        make_partition
    net = linear_network(n_routers=64, n_photons=64, period_ns=4000)
    cfg = EngineConfig(n_shards=1, pool_cap=16_384, qsm_cap=512,
                       outbox_cap=512, route_cap=64)
    sim = Simulator(net, make_partition(net, 1), cfg)
    t0 = time.perf_counter()
    res = sim.run(max_epochs=512, chunk=64)
    wall = time.perf_counter() - t0
    ev = int(res.metrics.events_by_kind.sum())
    out.append(("pdes_events_per_s_cpu", wall / max(ev, 1) * 1e6,
                f"{ev / wall:.0f}events/s"))
    return out


def main():
    print("# kernels_bench (CPU host measurements)")
    print("name,us_per_call,derived")
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
