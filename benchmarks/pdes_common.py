"""Shared machinery for the paper-reproduction benchmarks.

Real simulations run on this host (vmap over logical shards — same
collective semantics as the mesh path); per-shard event/wave/request
distributions are EXACT.  Times are projected through calibrated cost
models (costmodel.py): `SEQUENCE_PY` projects the CPython+MPI+socket
SeQUeNCe the paper measured; `TPU_POD`+vector model projects this engine.
Every CSV labels measured vs modeled columns.
"""
from __future__ import annotations

import json
import pickle
from pathlib import Path

import numpy as np

from repro.core import (
    EngineConfig, FRONTIER, TPU_POD, Simulator, as_network, breakdown,
    linear_network, make_partition,
)
from repro.core.costmodel import DEFAULT_VECTOR, SEQUENCE_PY

CACHE = Path(__file__).resolve().parent.parent / "experiments" / "cache"

# paper-scale workloads (1024 routers).  Emission periods chosen so the
# in-flight photon span (q_delay+c_delay)/period stays ~40-75 (bounds the
# QSM window and pool census on this host); the event-mix structure the
# paper identifies (quantum-channel events dominant) is preserved.
LINEAR_KW = dict(n_routers=1024, n_photons=32, period_ns=4_000,
                 hop_delay_ns=25_000, loss_p=0.1)
AS_KW = dict(n_routers=1024, n_as=32, n_photons=32, period_ns=8_000,
             seed=0)


def _cfg(S, mode="gathered"):
    # Buffer floors are sized for the STRAGGLER shard, not the average —
    # on the AS topology the hot shard holds a large share of all in-flight
    # events (the paper's whole point), so per-shard caps cannot shrink
    # proportionally with S.
    return EngineConfig(
        n_shards=S,
        pool_cap=max(262_144 // S, 32_768),
        qsm_cap=max(16_384 // S, 1_024),
        outbox_cap=max(32_768 // S, 2_048),
        route_cap=max(32_768 // S, 512),
        qsm_mode=mode)


# At S >= 256 the gathered mode's (S x S x qcap) all-gather staging exceeds
# this host's memory under vmap emulation.  The ENGINE then runs in hashed
# mode — event/wave/request distributions are bit-identical across QSM
# modes (verified at S <= 64 by beyond_qsm) — and the requested mode is
# used for the COST projection only.
ENGINE_MODE_SWITCH = 256


def run_sim(topology: str, S: int, mode: str = "gathered",
            scheme: str = "sa", steal: bool = False, cache: bool = True):
    """Run (or load cached) real simulation; returns summary dict."""
    CACHE.mkdir(parents=True, exist_ok=True)
    key = f"{topology}_S{S}_{mode}_{scheme}_steal{int(steal)}"
    path = CACHE / f"{key}.pkl"
    if cache and path.exists():
        return pickle.loads(path.read_bytes())

    net = linear_network(**LINEAR_KW) if topology == "linear" \
        else as_network(**AS_KW)
    part = make_partition(net, S, scheme=scheme if S > 1 else "contiguous")
    engine_mode = "hashed" if S >= ENGINE_MODE_SWITCH else mode
    sim = Simulator(net, part, _cfg(S, engine_mode))
    # stealing engages at chunk boundaries -> small chunks when stealing
    res = sim.run(max_epochs=100_000, chunk=2 if steal else 16,
                  steal_every=1 if steal else 0, steal_threshold=1.1)
    assert res.overflow == 0, f"{key}: pool overflow"
    assert res.stale_reads == 0, f"{key}: stale reads"

    m = res.metrics
    out = dict(
        key=key, topology=topology, S=S, mode=mode, scheme=scheme,
        steal=steal,
        n_epochs=res.n_epochs,
        sifted=int(res.sifted.sum()),
        qber=res.qber,
        events_by_kind=np.asarray(m.events_by_kind),   # (S,E,K)
        n_waves=np.asarray(m.n_waves),                 # (S,E)
        outbox_sent=np.asarray(m.outbox_sent),
        qsm_requests=np.asarray(m.qsm_requests),
        fingerprint=res.fingerprint(),
        steals=len(res.steals),
    )
    path.write_bytes(pickle.dumps(out))
    return out


class MetricsView:
    """Adapter so costmodel.breakdown can consume cached dicts."""

    def __init__(self, d):
        self.events_by_kind = d["events_by_kind"]
        self.n_waves = d["n_waves"]
        self.outbox_sent = d["outbox_sent"]
        self.qsm_requests = d["qsm_requests"]


def paper_breakdown(d, merge_wait=False, hw=FRONTIER, cm=SEQUENCE_PY):
    """EpochBreakdown under the paper-faithful projection (CPython event
    costs + Frontier comm constants)."""
    return breakdown(MetricsView(d), d["S"], hw, cm, qsm_mode=d["mode"],
                     merge_wait_into_compute=merge_wait)


def engine_breakdown(d, hw=TPU_POD, cm=DEFAULT_VECTOR):
    """Projection of THIS engine (vectorized waves, on-chip QSM)."""
    return breakdown(MetricsView(d), d["S"], hw, cm, qsm_mode=d["mode"])
