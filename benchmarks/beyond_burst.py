"""Beyond-paper §Perf (PDES iteration 3): burst emission.

One EMIT event emits up to 8 photons per wave instead of chaining one at a
time — the wave count per epoch (which sets the vectorized engine's
compute term: each wave is a full O(capacity) vector pass) collapses, with
bit-identical results.
"""
import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).parent))
from pdes_common import _cfg, AS_KW, engine_breakdown  # noqa

from repro.core import Simulator, as_network, make_partition  # noqa


def rows():
    import dataclasses
    out = []
    net = as_network(**AS_KW)
    for S in (8, 32):
        part = make_partition(net, S, scheme="sa")
        r0 = Simulator(net, part, _cfg(S)).run(chunk=16)
        r1 = Simulator(net, part,
                       dataclasses.replace(_cfg(S), burst_emit=True)
                       ).run(chunk=16)
        assert r0.fingerprint() == r1.fingerprint(), "burst diverged!"
        w0 = int(np.asarray(r0.metrics.n_waves).sum())
        w1 = int(np.asarray(r1.metrics.n_waves).sum())
        d0 = dict(S=S, mode="gathered",
                  events_by_kind=np.asarray(r0.metrics.events_by_kind),
                  n_waves=np.asarray(r0.metrics.n_waves),
                  outbox_sent=np.asarray(r0.metrics.outbox_sent),
                  qsm_requests=np.asarray(r0.metrics.qsm_requests))
        d1 = dict(d0, events_by_kind=np.asarray(r1.metrics.events_by_kind),
                  n_waves=np.asarray(r1.metrics.n_waves),
                  outbox_sent=np.asarray(r1.metrics.outbox_sent),
                  qsm_requests=np.asarray(r1.metrics.qsm_requests))
        t0 = engine_breakdown(d0).total_wall
        t1 = engine_breakdown(d1).total_wall
        out.append(dict(S=S, waves_base=w0, waves_burst=w1,
                        wave_reduction=w0 / max(w1, 1),
                        engine_total_base_s=t0, engine_total_burst_s=t1,
                        speedup=t0 / t1))
    return out


def main():
    print("# beyond_burst: burst emission (bit-identical; engine model)")
    print("S,waves_base,waves_burst,wave_reduction,"
          "engine_total_base_s,engine_total_burst_s,speedup")
    for r in rows():
        print(f"{r['S']},{r['waves_base']},{r['waves_burst']},"
              f"{r['wave_reduction']:.2f},{r['engine_total_base_s']:.5f},"
              f"{r['engine_total_burst_s']:.5f},{r['speedup']:.2f}")


if __name__ == "__main__":
    main()
