"""Fig 7 reproduction: per-process compute time per epoch (8 processes,
epochs bucketed) — "one process dominating the rest by a wide margin"."""
import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).parent))
from pdes_common import paper_breakdown, run_sim  # noqa

BUCKET = 4


def rows(S=8):
    d = run_sim("as", S)
    bd = paper_breakdown(d)
    comp = bd.compute  # (S, E)
    E = comp.shape[1]
    nb = E // BUCKET
    out = []
    for b in range(nb):
        seg = comp[:, b * BUCKET:(b + 1) * BUCKET].sum(axis=1)
        out.append([b] + seg.tolist())
    return out, comp


def main():
    data, comp = rows()
    S = comp.shape[0]
    print(f"# fig7_perprocess: AS, {S} processes, compute time per "
          f"{BUCKET}-epoch bucket (s)")
    print("bucket," + ",".join(f"p{i}" for i in range(S)))
    for row in data:
        print(f"{row[0]}," + ",".join(f"{v:.4f}" for v in row[1:]))
    tot = comp.sum(axis=1)
    print(f"# per-process totals: {np.round(tot, 3).tolist()}")
    print(f"# dominance max/median: {tot.max() / np.median(tot):.2f}")


if __name__ == "__main__":
    main()
