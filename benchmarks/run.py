"""Benchmark entrypoint: one section per paper figure/table + beyond-paper
comparisons + kernel microbenches + the roofline report.

``PYTHONPATH=src python -m benchmarks.run [--only SECTION]``
"""
import argparse
import sys
import time
import traceback
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run a single section by name")
    args = ap.parse_args()

    import fig3_linear
    import fig4_as
    import fig5_breakdown
    import fig6_redefined
    import fig7_perprocess
    import beyond_burst
    import beyond_qsm
    import beyond_stealing
    import kernels_bench
    import roofline_report

    sections = [
        ("kernels_bench", kernels_bench.main),
        ("fig3_linear", fig3_linear.main),
        ("fig4_as", fig4_as.main),
        ("fig5_breakdown", fig5_breakdown.main),
        ("fig6_redefined", fig6_redefined.main),
        ("fig7_perprocess", fig7_perprocess.main),
        ("beyond_qsm", beyond_qsm.main),
        ("beyond_stealing", beyond_stealing.main),
        ("beyond_burst", beyond_burst.main),
        ("roofline_report", roofline_report.main),
    ]
    failures = []
    for name, fn in sections:
        if args.only and name != args.only:
            continue
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            fn()
            print(f"# ({name}: {time.time() - t0:.0f}s)")
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        print(f"\nFAILED sections: {failures}")
        raise SystemExit(1)
    print("\nall benchmark sections completed")


if __name__ == "__main__":
    main()
