"""§Perf hillclimbing driver: lower a cell with config overrides, extract
roofline terms, and append the hypothesis→change→before→after record.

    PYTHONPATH=src python benchmarks/perf_hillclimb.py \
        --arch moonshot-v1-16b-a3b --shape train_4k \
        --set moe_dp_slices=16 --tag sliced_dispatch
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
from pathlib import Path  # noqa: E402

PERF_DIR = Path(__file__).resolve().parent.parent / "experiments" / "perf"


def parse_override(s: str):
    k, v = s.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            continue
    if v in ("True", "False"):
        return k, v == "True"
    return k, v


def run(arch, shape_name, overrides, tag, mesh_kind="single"):
    from repro.configs import registry as R
    from repro.launch import roofline as RL
    from repro.launch.dryrun import _depth_scaled, lower_cell
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    cfg = R.get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **dict(overrides))
    shape = R.SHAPES[shape_name]
    total, active = RL.count_params(cfg)
    mf = RL.model_flops_for(cfg, shape, total, active)

    t0 = time.time()
    terms12 = []
    for r in (1, 2):
        _, comp = lower_cell(_depth_scaled(cfg, r), shape, mesh)
        terms12.append(RL.analyze(comp.cost_analysis(), comp.as_text(),
                                  mesh.devices.size, mf))
    terms = RL.extrapolate(terms12[0], terms12[1], cfg.pattern_repeats)
    rec = dict(arch=arch, shape=shape_name, tag=tag,
               overrides=dict(overrides), mesh=mesh_kind,
               roofline=terms.as_dict(),
               elapsed_s=round(time.time() - t0, 1))
    PERF_DIR.mkdir(parents=True, exist_ok=True)
    out = PERF_DIR / f"{arch}__{shape_name}__{tag}.json"
    out.write_text(json.dumps(rec, indent=1))
    r = rec["roofline"]
    print(f"[perf] {arch} x {shape_name} [{tag}] "
          f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
          f"coll={r['collective_s']:.3e}s dominant={r['dominant']} "
          f"({rec['elapsed_s']}s)")
    print(f"       coll_by_kind: "
          f"{ {k: f'{v/1e9:.1f}GB' for k, v in r['coll_by_kind'].items()} }")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (repeatable)")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    overrides = [parse_override(s) for s in args.set]
    run(args.arch, args.shape, overrides, args.tag, args.mesh)


if __name__ == "__main__":
    main()
