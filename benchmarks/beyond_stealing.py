"""Beyond-paper: dynamic work stealing (the paper's §IV proposal, built).

Same AS workload, static SA partition vs chunk-boundary stealing.  Results
are bit-identical (fingerprint check); the critical path (max per-shard
events, which the straggler sets) drops.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent))
from pdes_common import paper_breakdown, run_sim  # noqa

SCALES = [8, 16, 32]


def rows():
    out = []
    for S in SCALES:
        a = run_sim("as", S, steal=False)
        b = run_sim("as", S, steal=True)
        assert a["fingerprint"] == b["fingerprint"], "stealing diverged!"
        ev_a = a["events_by_kind"].sum(-1).sum(1)
        ev_b = b["events_by_kind"].sum(-1).sum(1)
        ba, bb = paper_breakdown(a), paper_breakdown(b)
        out.append(dict(
            S=S,
            static_max_events=int(ev_a.max()),
            steal_max_events=int(ev_b.max()),
            static_imb=float(ev_a.max() / max(ev_a.mean(), 1e-9)),
            steal_imb=float(ev_b.max() / max(ev_b.mean(), 1e-9)),
            static_total_s=ba.total_wall,
            steal_total_s=bb.total_wall,
            moves=b["steals"]))
    return out


def main():
    print("# beyond_stealing: static SA partition vs dynamic work stealing "
          "(bit-identical results verified)")
    print("S,static_max_events,steal_max_events,static_imb,steal_imb,"
          "static_total_s,steal_total_s,steal_rounds")
    for r in rows():
        print(f"{r['S']},{r['static_max_events']},{r['steal_max_events']},"
              f"{r['static_imb']:.2f},{r['steal_imb']:.2f},"
              f"{r['static_total_s']:.4f},{r['steal_total_s']:.4f},"
              f"{r['moves']}")


if __name__ == "__main__":
    main()
