"""Fig 4 reproduction: autonomous-system topology strong scaling, 1-512.

The paper's finding: maximum performance at a mere 16 processes, after
which synchronisation costs outweigh the decreased compute share.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent))
from pdes_common import engine_breakdown, paper_breakdown, run_sim  # noqa

SCALES = [1, 2, 4, 8, 16, 32, 64, 128, 256]  # S=512: single-core host budget, see EXPERIMENTS.md


def rows():
    out = []
    base = None
    for S in SCALES:
        d = run_sim("as", S)
        bd = paper_breakdown(d)
        av = bd.averages()
        total = bd.total_wall
        if base is None:
            base = total
        ev = d["events_by_kind"].sum(-1)
        imb = float(ev.sum(1).max() / max(ev.sum(1).mean(), 1e-9))
        out.append(dict(
            S=S, compute_s=av["compute"], socket_s=av["qsm"],
            mpi_s=av["wait"] + av["comm"], total_s=total,
            speedup=base / total, event_imbalance=imb,
            engine_total_s=engine_breakdown(d).total_wall))
    return out


def main():
    print("# fig4_as: projected SeQUeNCe-like; peak-then-degrade expected")
    print("S,compute_s,socket_s,mpi_s,total_s,speedup,event_imbalance,"
          "engine_total_s")
    for r in rows():
        print(f"{r['S']},{r['compute_s']:.4f},{r['socket_s']:.4f},"
              f"{r['mpi_s']:.4f},{r['total_s']:.4f},{r['speedup']:.2f},"
              f"{r['event_imbalance']:.2f},{r['engine_total_s']:.5f}")


if __name__ == "__main__":
    main()
