"""Fig 5 reproduction: the paper's KEY contribution — barrier-split timing.

Splits the lumped "MPI" interval into straggler WAIT vs actual
COMMUNICATION, showing "network communication was never actually a
significant concern": wait dominates comm by orders of magnitude.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent))
from pdes_common import paper_breakdown, run_sim  # noqa

SCALES = [2, 4, 8, 16, 32, 64, 128, 256]


def rows():
    out = []
    for S in SCALES:
        d = run_sim("as", S)
        av = paper_breakdown(d).averages()
        out.append(dict(S=S, compute_s=av["compute"], wait_s=av["wait"],
                        comm_s=av["comm"], socket_s=av["qsm"],
                        wait_over_comm=(av["wait"] / av["comm"]
                                        if av["comm"] else float("inf"))))
    return out


def main():
    print("# fig5_breakdown: wait (stragglers) vs comm (actual MPI), AS")
    print("S,compute_s,wait_s,comm_s,socket_s,wait_over_comm")
    for r in rows():
        print(f"{r['S']},{r['compute_s']:.4f},{r['wait_s']:.4f},"
              f"{r['comm_s']:.6f},{r['socket_s']:.4f},"
              f"{r['wait_over_comm']:.1f}")


if __name__ == "__main__":
    main()
