"""ZeRO-1: shard AdamW moments over the data-parallel axes.

Given the parameter PartitionSpecs (TP over "model"), each moment tensor
additionally shards its largest un-sharded, divisible dimension over
("pod","data") — first-moment+second-moment memory drops by ~DP degree,
which is what lets the 110B config fit 16 GB/chip HBM at 256 chips.
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.sharding import dp_axes, param_specs


def _zero1_spec(spec: P, shape, dp: tuple, dp_size: int) -> P:
    entries = list(spec) + [None] * (len(shape) - len(spec))
    # pick the largest dim that is unsharded and divisible by dp_size
    best, best_size = None, 0
    for i, (s, n) in enumerate(zip(entries, shape)):
        if s is None and n % dp_size == 0 and n > best_size:
            best, best_size = i, n
    if best is not None:
        entries[best] = dp if len(dp) > 1 else dp[0]
    return P(*entries)


def zero1_param_specs(params, mesh: Mesh):
    """Specs for optimizer-moment tensors (params' TP spec + DP sharding)."""
    tp = mesh.shape.get("model", 1)
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    base = param_specs(params, tp)

    def walk(p, s):
        return _zero1_spec(s, p.shape, dp, dp_size) if dp else s

    return jax.tree.map(walk, params, base,
                        is_leaf=lambda x: isinstance(x, P))


def zero1_shardings(params, mesh: Mesh):
    specs = zero1_param_specs(params, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
