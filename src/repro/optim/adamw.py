"""AdamW from scratch (mixed precision: bf16/f32 params, f32 moments)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: dict
    nu: dict
    count: jnp.ndarray


def init(params) -> AdamWState:
    f32zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        mu=jax.tree.map(f32zeros, params),
        nu=jax.tree.map(f32zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def update(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.95,
           eps=1e-8, weight_decay=0.1, grad_clip=1.0):
    count = state.count + 1
    # global-norm clip
    gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))

    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / bc1
        vhat = nu / bc2
        step = mhat / (jnp.sqrt(vhat) + eps) + \
            weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(new_mu, new_nu, count), gnorm
