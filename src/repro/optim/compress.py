"""Error-feedback int8 gradient compression (distributed-optimization trick).

Before the DP all-reduce, gradients are quantized to int8 with a per-tensor
scale; the quantization error is carried in a residual buffer and added back
next step (error feedback keeps SGD/Adam convergence).  8x less gradient
traffic on the DP axis — applied optionally in the trainer
(``TrainConfig.grad_compress=True``) and billed in the §Perf analysis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(g, r):
    """Quantize (g+r) to int8, return (dequantized, new residual)."""
    gf = g.astype(jnp.float32) + r
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq.astype(g.dtype), gf - deq


def apply(grads, residuals):
    out = jax.tree.map(compress_decompress, grads, residuals)
    deq = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    return deq, res
