"""Sharded, atomic, async checkpointing (no external deps).

Layout: <dir>/step_<N>/
  manifest.msgpack   — tree structure, leaf shapes/dtypes, step, config hash
  arrays.npz         — leaf arrays keyed by flattened path

Writes go to a temp dir + atomic rename, so a failure mid-write never
corrupts the latest checkpoint; `latest_step` scans completed dirs only.
An optional background thread makes saves non-blocking (the training loop
keeps stepping while the previous state serializes — fault-tolerance trick
#1 for large fleets).  Restore is exact: tree structure, dtypes, and the
data-pipeline step counter all round-trip.
"""
from __future__ import annotations

import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import msgpack
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(directory: str | Path, step: int, tree: Any,
         extra: Optional[dict] = None, _sync: bool = True):
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f".tmp_step_{step}"
    final = directory / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat = _flatten(tree)
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {
        "step": step,
        "keys": list(flat.keys()),
        "treedef": str(treedef),
        "extra": extra or {},
    }
    np.savez(tmp / "arrays.npz", **flat)
    (tmp / "manifest.msgpack").write_bytes(
        msgpack.packb(manifest, use_bin_type=True))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


class AsyncCheckpointer:
    """Overlaps serialization with training; at most one save in flight."""

    def __init__(self, directory: str | Path, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        # device->host copy happens here (cheap on CPU; on TPU this is the
        # only sync part), serialization runs in the thread
        host_tree = jax.tree.map(np.asarray, tree)

        def work():
            try:
                save(self.directory, step, host_tree, extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self.error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.error is not None:
            err, self.error = self.error, None
            raise err

    def _gc(self):
        steps = sorted(all_steps(self.directory))
        for s in steps[:-self.keep]:
            shutil.rmtree(self.directory / f"step_{s}", ignore_errors=True)


def all_steps(directory: str | Path):
    directory = Path(directory)
    if not directory.exists():
        return []
    out = []
    for d in directory.iterdir():
        if d.name.startswith("step_") and (d / "manifest.msgpack").exists():
            out.append(int(d.name.split("_")[1]))
    return sorted(out)


def latest_step(directory: str | Path) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str | Path, step: int, like: Any,
            shardings: Any = None):
    """Restore into the structure of `like` (validates shapes/dtypes).
    `shardings` (optional pytree) device_puts each leaf to its sharding —
    this is also the elastic-resize path (same arrays, new mesh)."""
    d = Path(directory) / f"step_{step}"
    manifest = msgpack.unpackb((d / "manifest.msgpack").read_bytes(),
                               raw=False)
    with np.load(d / "arrays.npz") as z:
        flat = {k: z[k] for k in manifest["keys"]}

    ref = _flatten(like)
    if set(ref.keys()) != set(flat.keys()):
        missing = set(ref) - set(flat)
        extra_k = set(flat) - set(ref)
        raise ValueError(f"checkpoint mismatch: missing={missing} "
                         f"unexpected={extra_k}")
    for k, v in ref.items():
        if tuple(flat[k].shape) != tuple(v.shape):
            raise ValueError(f"shape mismatch at {k}: "
                             f"{flat[k].shape} vs {v.shape}")

    leaves_ref, treedef = jax.tree_util.tree_flatten(like)
    keys_in_order = list(_flatten(like).keys())
    leaves = [flat[k].astype(np.asarray(r).dtype)
              for k, r in zip(keys_in_order, leaves_ref)]
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree, manifest["extra"]
