"""Batched decode server with continuous batching over fixed slots.

Requests occupy batch slots; every engine step decodes one token for every
active slot; finished slots (EOS or budget) are refilled from the queue —
the standard large-scale serving pattern, here CPU-runnable end to end.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import api


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class DecodeServer:
    def __init__(self, cfg: ArchConfig, params, *, slots: int = 4,
                 max_seq: int = 256, greedy: bool = True):
        self.cfg, self.params = cfg, params
        self.slots, self.max_seq = slots, max_seq
        self.queue: deque[Request] = deque()
        self.active: List[Optional[Request]] = [None] * slots
        self.caches = api.make_caches(cfg, slots, max_seq, jnp.float32)
        self._last_tok = np.zeros((slots, 1), np.int32)
        self._len = np.zeros((slots,), np.int32)
        self._decode = jax.jit(
            lambda b, c: api.decode_step(params, cfg, b, c))
        self._greedy = greedy

    def submit(self, req: Request):
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _admit(self):
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.popleft()
                self.active[s] = req
                # prefill via repeated decode (slot-local; simple and exact)
                self._reset_slot_cache(s)
                self._len[s] = 0
                for t in req.prompt:
                    self._step_slot_token(s, t)
                # _last_tok now holds the final prompt token; the next
                # engine step produces the first generated token.

    def _reset_slot_cache(self, s):
        def zero_slot(leaf):
            if leaf.ndim >= 2 and leaf.shape[1] == self.slots:
                return leaf.at[:, s].set(jnp.zeros_like(leaf[:, s]))
            return leaf
        self.caches = jax.tree.map(zero_slot, self.caches)

    def _step_slot_token(self, s, tok):
        self._last_tok[s, 0] = tok
        batch = {"token": jnp.asarray(self._last_tok)}
        logits, self.caches = self._decode(batch, self.caches)
        self._len[s] += 1
        self._logits = logits

    # ------------------------------------------------------------------
    def step(self):
        """One engine step: decode one token for all active slots."""
        self._admit()
        if not any(r is not None for r in self.active):
            return False
        batch = {"token": jnp.asarray(self._last_tok)}
        logits, self.caches = self._decode(batch, self.caches)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        for s, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(nxt[s])
            req.output.append(tok)
            self._last_tok[s, 0] = tok
            self._len[s] += 1
            if (len(req.output) >= req.max_new_tokens
                    or (req.eos_id is not None and tok == req.eos_id)
                    or self._len[s] >= self.max_seq - 1):
                req.done = True
                self.active[s] = None
        return True

    def run(self, max_steps: int = 10_000):
        done: List[Request] = []
        n = 0
        while n < max_steps and (self.queue or
                                 any(self.active)):
            before = [r for r in self.active if r]
            self.step()
            done.extend(r for r in before if r.done)
            n += 1
        return done
