"""Training runtime: sharded step loop + async checkpointing + telemetry +
deterministic resume.

Fault tolerance story (tested in tests/test_runtime.py):
  * checkpoints are atomic + async (checkpoint/checkpointer.py),
  * the data pipeline is a pure function of step -> restart is exact
    skip-ahead (bit-identical loss curve after a crash/resume),
  * elastic re-mesh = restore with new shardings (runtime/elastic.py).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import checkpointer as CK
from repro.configs.base import ArchConfig
from repro.data.synthetic import DataConfig, make_batch
from repro.models import api
from repro.optim import adamw
from repro.parallel import sharding as SH
from repro.runtime.telemetry import StepTimer


@dataclasses.dataclass
class TrainConfig:
    arch: ArchConfig
    steps: int = 100
    lr: float = 3e-4
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    grad_compress: bool = False
    param_dtype: jnp.dtype = jnp.float32


class Trainer:
    def __init__(self, cfg: TrainConfig, mesh=None):
        self.cfg = cfg
        self.mesh = mesh
        self.data_cfg = DataConfig(vocab_size=cfg.arch.vocab_size,
                                   seq_len=cfg.seq_len,
                                   global_batch=cfg.global_batch,
                                   seed=cfg.seed)
        self.step = 0
        self.timer = StepTimer()
        self.ckpt = (CK.AsyncCheckpointer(cfg.ckpt_dir)
                     if cfg.ckpt_dir else None)
        self._build()
        if self.ckpt is not None:
            self._maybe_resume()

    # ------------------------------------------------------------------
    def _build(self):
        cfg = self.cfg
        params = api.init_params(cfg.arch, jax.random.key(cfg.seed),
                                 cfg.param_dtype)
        opt = adamw.init(params)
        if self.mesh is not None:
            p_sh = SH.param_shardings(params, self.mesh)
            params = jax.tree.map(jax.device_put, params, p_sh)
            from repro.optim.zero import zero1_shardings
            mu_sh = zero1_shardings(params, self.mesh)
            opt = adamw.AdamWState(
                mu=jax.tree.map(jax.device_put, opt.mu, mu_sh),
                nu=jax.tree.map(jax.device_put, opt.nu, mu_sh),
                count=opt.count)
        self.params, self.opt = params, opt
        arch, lr = cfg.arch, cfg.lr

        def step_fn(params, opt, batch):
            (loss, aux), grads = jax.value_and_grad(
                api.loss_fn, has_aux=True)(params, arch, batch)
            params, opt, gnorm = adamw.update(grads, opt, params, lr=lr)
            metrics = {"loss": loss, "gnorm": gnorm,
                       "moe_dropped": aux["moe_dropped"]}
            return params, opt, metrics

        ctx = SH.activate_mesh(self.mesh) if self.mesh else None
        self._step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
        self._mesh_ctx = ctx

    def _maybe_resume(self):
        latest = CK.latest_step(self.cfg.ckpt_dir)
        if latest is None:
            return
        (self.params, self.opt), extra = CK.restore(
            self.cfg.ckpt_dir, latest, (self.params, self.opt))
        self.step = int(extra["step"])

    # ------------------------------------------------------------------
    def train(self, n_steps: Optional[int] = None):
        n = n_steps if n_steps is not None else self.cfg.steps
        target = self.step + n
        while self.step < target:
            batch = make_batch(self.data_cfg, self.step)
            if self.mesh is not None:
                bs = SH.batch_sharding(self.mesh, batch["tokens"].shape,
                                       batch_size=self.cfg.global_batch)
                batch = {"tokens": jax.device_put(batch["tokens"], bs)}
            self.timer.start()
            if self.mesh is not None:
                with SH.activate_mesh(self.mesh):
                    self.params, self.opt, m = self._step_fn(
                        self.params, self.opt, batch)
            else:
                self.params, self.opt, m = self._step_fn(
                    self.params, self.opt, batch)
            loss = float(m["loss"])
            self.timer.stop(self.step, loss, float(m["gnorm"]))
            self.step += 1
            if (self.ckpt is not None and
                    self.step % self.cfg.ckpt_every == 0):
                self.ckpt.save(self.step, (self.params, self.opt),
                               extra={"step": self.step})
        if self.ckpt is not None:
            self.ckpt.save(self.step, (self.params, self.opt),
                           extra={"step": self.step})
            self.ckpt.wait()
        return self.timer.summary()
