"""Step telemetry with the paper's barrier-split decomposition.

The paper's key instrument (Fig 5): insert a barrier between local work and
communication so "time lost waiting for stragglers" is not booked as
communication time.  Ported to training steps:

  * wall time per step (measured),
  * straggler-wait estimate from REAL load imbalance telemetry — MoE
    per-expert token loads (token-level stragglers) and per-data-shard
    token counts — using wait ≈ wall_compute * (max/mean - 1),
  * collective time from the dry-run roofline terms when available.

On real multi-host TPU the same class wraps an explicit device barrier
(psum of a scalar) between the compute and collective phases; on this
CPU-only container the decomposition comes from the load telemetry, which
is exactly the quantity the paper shows partitioning cannot fix.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class StepRecord:
    step: int
    wall_s: float
    loss: float
    grad_norm: float
    expert_imbalance: float = 1.0   # max/mean per-expert load (1.0 = even)
    wait_frac_est: float = 0.0      # straggler-wait share of the step
    comm_s_model: float = 0.0       # modeled collective time (roofline)


class StepTimer:
    def __init__(self, comm_s_model: float = 0.0):
        self.records: List[StepRecord] = []
        self.comm_s_model = comm_s_model
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int, loss: float, grad_norm: float,
             expert_load: Optional[np.ndarray] = None):
        wall = time.perf_counter() - self._t0
        imb, wait = 1.0, 0.0
        if expert_load is not None and expert_load.size:
            load = np.asarray(expert_load, float)
            mean = load.mean() if load.mean() > 0 else 1.0
            imb = float(load.max() / mean)
            # expert-parallel critical path waits for the hottest expert
            wait = max(0.0, (imb - 1.0) / imb)
        rec = StepRecord(step=step, wall_s=wall, loss=loss,
                         grad_norm=grad_norm, expert_imbalance=imb,
                         wait_frac_est=wait,
                         comm_s_model=self.comm_s_model)
        self.records.append(rec)
        return rec

    def summary(self) -> dict:
        if not self.records:
            return {}
        w = np.array([r.wall_s for r in self.records[1:] or self.records])
        return dict(
            steps=len(self.records),
            mean_step_s=float(w.mean()),
            p50_step_s=float(np.percentile(w, 50)),
            p95_step_s=float(np.percentile(w, 95)),
            mean_expert_imbalance=float(np.mean(
                [r.expert_imbalance for r in self.records])),
            mean_wait_frac=float(np.mean(
                [r.wait_frac_est for r in self.records])),
            final_loss=self.records[-1].loss,
        )

    def to_csv(self, path):
        import csv
        with open(path, "w", newline="") as f:
            wr = csv.writer(f)
            wr.writerow([f.name for f in dataclasses.fields(StepRecord)])
            for r in self.records:
                wr.writerow(dataclasses.astuple(r))
