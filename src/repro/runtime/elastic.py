"""Elastic scaling + failure handling.

Policy (designed for 1000+ nodes, exercised logically here):
  1. a heartbeat monitor marks a host failed after `timeout` missed beats,
  2. the controller shrinks the 'data' axis to the largest power-of-two
     that the surviving hosts support (TP groups must stay intact — losing
     one host of a model-parallel group removes the whole group),
  3. state is restored from the latest atomic checkpoint with the NEW
     mesh's shardings (checkpointer.restore(shardings=...)),
  4. the deterministic data pipeline re-shards by skip-ahead; the global
     batch is preserved (per-shard microbatch grows), so the loss curve is
     unchanged modulo the rolled-back steps.

The same controller handles scale-UP (recovered hosts rejoin at the next
checkpoint boundary).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass
class HostState:
    host_id: int
    last_beat: float
    alive: bool = True


class HeartbeatMonitor:
    def __init__(self, n_hosts: int, timeout_s: float = 60.0):
        now = time.time()
        self.hosts: Dict[int, HostState] = {
            h: HostState(h, now) for h in range(n_hosts)}
        self.timeout_s = timeout_s

    def beat(self, host_id: int, when: Optional[float] = None):
        self.hosts[host_id].last_beat = \
            time.time() if when is None else when

    def sweep(self, now: Optional[float] = None):
        now = time.time() if now is None else now
        failed = []
        for h in self.hosts.values():
            if h.alive and now - h.last_beat > self.timeout_s:
                h.alive = False
                failed.append(h.host_id)
        return failed

    @property
    def alive_count(self):
        return sum(h.alive for h in self.hosts.values())


def plan_remesh(alive_hosts: int, hosts_per_tp_group: int,
                old_data_axis: int):
    """Largest power-of-two data axis the surviving hosts support."""
    groups = alive_hosts // hosts_per_tp_group
    if groups < 1:
        raise RuntimeError("not enough hosts for one model-parallel group")
    new_data = 1 << int(np.floor(np.log2(groups)))
    return min(new_data, old_data_axis * 2)


@dataclasses.dataclass
class ElasticEvent:
    kind: str           # "shrink" | "grow"
    old_data: int
    new_data: int
    restored_step: int


class ElasticController:
    """Drives fail -> re-mesh -> restore -> resume for a Trainer-like
    object exposing (ckpt_dir, rebuild(mesh_data_axis) -> restored_step)."""

    def __init__(self, monitor: HeartbeatMonitor, hosts_per_tp_group: int,
                 data_axis: int):
        self.monitor = monitor
        self.hosts_per_tp_group = hosts_per_tp_group
        self.data_axis = data_axis
        self.events = []

    def check(self, rebuild, now: Optional[float] = None):
        failed = self.monitor.sweep(now)
        if not failed:
            return None
        new_data = plan_remesh(self.monitor.alive_count,
                               self.hosts_per_tp_group, self.data_axis)
        if new_data == self.data_axis:
            return None
        restored = rebuild(new_data)
        ev = ElasticEvent("shrink" if new_data < self.data_axis else "grow",
                          self.data_axis, new_data, restored)
        self.data_axis = new_data
        self.events.append(ev)
        return ev
