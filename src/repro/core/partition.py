"""Network partitioning (host-side preprocessing).

The paper reproduces [14]'s simulated-annealing partitioner but restricts the
energy function to *topology-only* knowledge: the number of cross-process
quantum channels.  We implement exactly that as `simulated_annealing`, plus
baselines (`contiguous`, `random_partition`) and the beyond-paper
`greedy_load_balance` that uses per-router predicted load (sessions touching
the router) — the kind of workload knowledge the paper argues one should not
have to require, included so benchmarks can quantify how much it buys.
"""
from __future__ import annotations

import numpy as np

from repro.core.topology import Network


def contiguous(net: Network, n_parts: int) -> np.ndarray:
    """Block partition by router id (natural for the linear topology)."""
    return (np.arange(net.n_routers) * n_parts // net.n_routers).astype(
        np.int32)


def random_partition(net: Network, n_parts: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, n_parts, size=net.n_routers).astype(np.int32)


def cut_channels(net: Network, part: np.ndarray) -> int:
    """Energy function from the paper: cross-partition quantum channels."""
    return int(sum(1 for c in net.channels if part[c.u] != part[c.v]))


def cut_sessions(net: Network, part: np.ndarray) -> int:
    return int(sum(1 for s in net.sessions if part[s.src] != part[s.dst]))


def router_load(net: Network) -> np.ndarray:
    """Predicted per-router event load: photons of sessions touching it."""
    load = np.zeros(net.n_routers, dtype=np.int64)
    for s in net.sessions:
        load[s.src] += s.n_photons
        load[s.dst] += s.n_photons
    return load


def load_imbalance(net: Network, part: np.ndarray, n_parts: int) -> float:
    """max/mean per-part predicted load (1.0 = perfectly balanced)."""
    load = router_load(net)
    per = np.zeros(n_parts, dtype=np.int64)
    np.add.at(per, part, load)
    mean = per.mean() if per.mean() > 0 else 1.0
    return float(per.max() / mean)


def simulated_annealing(
    net: Network,
    n_parts: int,
    seed: int = 0,
    n_steps: int = 20_000,
    t0: float = 2.0,
    t1: float = 0.01,
    balance_slack: float = 0.25,
    init: np.ndarray | None = None,
) -> np.ndarray:
    """SA over router→part assignment, energy = cross-part quantum channels.

    A hard per-part size constraint (within `balance_slack` of even) mirrors
    the router-count balancing the upstream partitioner applies; the energy
    itself is topology-only, per the paper.
    """
    rng = np.random.default_rng(seed)
    part = (init if init is not None else contiguous(net, n_parts)).copy()
    n = net.n_routers
    cap = int(np.ceil(n / n_parts * (1.0 + balance_slack)))
    sizes = np.bincount(part, minlength=n_parts)

    # adjacency lists for incremental energy deltas
    nbrs: list[list[int]] = [[] for _ in range(n)]
    for c in net.channels:
        nbrs[c.u].append(c.v)
        nbrs[c.v].append(c.u)

    energy = cut_channels(net, part)
    temps = np.geomspace(t0, t1, num=n_steps)
    for step in range(n_steps):
        r = int(rng.integers(n))
        p_new = int(rng.integers(n_parts))
        p_old = int(part[r])
        if p_new == p_old or sizes[p_new] >= cap:
            continue
        delta = 0
        for v in nbrs[r]:
            pv = part[v]
            delta += int(pv != p_new) - int(pv != p_old)
        if delta <= 0 or rng.random() < np.exp(-delta / temps[step]):
            part[r] = p_new
            sizes[p_old] -= 1
            sizes[p_new] += 1
            energy += delta
    assert energy == cut_channels(net, part)
    return part.astype(np.int32)


def greedy_load_balance(net: Network, n_parts: int) -> np.ndarray:
    """Beyond-paper: LPT bin-packing on predicted router load, then a local
    cut-reduction sweep that only accepts moves preserving balance."""
    load = router_load(net)
    order = np.argsort(-load)
    per = np.zeros(n_parts, dtype=np.int64)
    part = np.zeros(net.n_routers, dtype=np.int32)
    for r in order:
        p = int(np.argmin(per))
        part[r] = p
        per[p] += max(int(load[r]), 1)

    nbrs: list[list[int]] = [[] for _ in range(net.n_routers)]
    for c in net.channels:
        nbrs[c.u].append(c.v)
        nbrs[c.v].append(c.u)
    mean = per.mean()
    for _ in range(2):
        for r in range(net.n_routers):
            if not nbrs[r]:
                continue
            votes = np.bincount([part[v] for v in nbrs[r]],
                                minlength=n_parts)
            p_best = int(np.argmax(votes))
            p_old = int(part[r])
            if p_best != p_old and votes[p_best] > votes[p_old]:
                if per[p_best] + load[r] <= 1.15 * mean + load[r]:
                    per[p_old] -= max(int(load[r]), 1)
                    per[p_best] += max(int(load[r]), 1)
                    part[r] = p_best
    return part


def make_partition(net: Network, n_parts: int, scheme: str = "sa",
                   seed: int = 0) -> np.ndarray:
    if n_parts == 1:
        return np.zeros(net.n_routers, dtype=np.int32)
    if scheme == "contiguous":
        return contiguous(net, n_parts)
    if scheme == "random":
        return random_partition(net, n_parts, seed)
    if scheme == "sa":
        return simulated_annealing(net, n_parts, seed)
    if scheme == "greedy_load":
        return greedy_load_balance(net, n_parts)
    raise ValueError(f"unknown partition scheme: {scheme}")
