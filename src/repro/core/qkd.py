"""BB84 QKD event handlers, fully vectorized over pool slots.

Event flow per photon (matching the dominant-event structure the paper's
workload analysis identifies — quantum-channel events dominate):

  EMIT(sender)    -> prepare (bit, tx_basis); write to sender local store and
                     (cross-shard sessions) to the global QSM; schedule
                     ARRIVE(t+q_delay) and the next EMIT(t+period).
  ARRIVE(recv)    -> photon lost w.p. loss_p; if detected, choose rx_basis;
                     local sessions measure against the local store in-wave;
                     cross-shard sessions enqueue a QSM MEASURE request
                     (processed batched at epoch end, like SeQUeNCe's
                     batched socket requests).
  CLASSICAL(send) -> basis reconciliation; matched bases contribute a sifted
                     key bit (XOR-folded into key_hash) and QBER errors.

Handlers compute over ALL pool slots and apply under an execution mask, so a
wave costs O(capacity) vector work regardless of how many events fire.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import rng
from repro.core.types import (
    KIND_ARRIVE, KIND_CLASSICAL, KIND_EMIT, QSM_MEASURE, QSM_WRITE,
    EventPool, QsmStore, SessionState, Staged,
)

# photon uid packing: uid = session << PHOTON_BITS | photon
PHOTON_BITS = 16
MAX_PHOTONS = 1 << PHOTON_BITS


class StaticTables(NamedTuple):
    """Replicated per-session parameter tables + topology maps."""

    src: jnp.ndarray       # int32[S_n] sender router
    dst: jnp.ndarray       # int32[S_n] receiver router
    n_photons: jnp.ndarray
    period: jnp.ndarray
    q_delay: jnp.ndarray
    c_delay: jnp.ndarray
    loss_p: jnp.ndarray    # float32[S_n]
    start: jnp.ndarray
    n_routers: int
    n_sessions: int


class HandlerOut(NamedTuple):
    staged: Staged              # new events ((burst+1) slots per pool slot)
    sess: SessionState
    local_store: QsmStore
    qsm_op: jnp.ndarray         # int32[cap*burst] QSM request ops
    qsm_session: jnp.ndarray    # int32[cap*burst]
    qsm_photon: jnp.ndarray     # int32[cap*burst]
    qsm_payload: jnp.ndarray    # int32[cap*burst]
    qsm_reply_time: jnp.ndarray  # int32[cap*burst]
    stale: jnp.ndarray          # int32[] stale local-store reads


def _uid(session: jnp.ndarray, photon: jnp.ndarray) -> jnp.ndarray:
    return (session << PHOTON_BITS) | photon


def pack_classical(outcome, rx_basis, detected):
    return outcome | (rx_basis << 1) | (detected << 2)


def unpack_classical(a2):
    return a2 & 1, (a2 >> 1) & 1, (a2 >> 2) & 1


def store_write(store: QsmStore, sess_ids, photons, bits, bases, mask):
    w = store.window
    col = photons % w
    sid = jnp.where(mask, sess_ids, store.bit.shape[0])  # OOB -> dropped
    return QsmStore(
        bit=store.bit.at[sid, col].set(bits, mode="drop"),
        basis=store.basis.at[sid, col].set(bases, mode="drop"),
        stamp=store.stamp.at[sid, col].set(photons, mode="drop"),
    )


def store_read(store: QsmStore, sess_ids, photons):
    """Returns (bit, basis, fresh) — fresh=False on window reuse (stale)."""
    w = store.window
    col = photons % w
    sid = jnp.clip(sess_ids, 0, store.bit.shape[0] - 1)
    fresh = store.stamp[sid, col] == photons
    return store.bit[sid, col], store.basis[sid, col], fresh


def _session_is_local(tables: StaticTables, router_owner, sess_ids):
    s = jnp.clip(sess_ids, 0, tables.n_sessions - 1)
    return router_owner[tables.src[s]] == router_owner[tables.dst[s]]


def handle_all(
    pool: EventPool,
    exec_mask: jnp.ndarray,
    sess: SessionState,
    local_store: QsmStore,
    router_owner: jnp.ndarray,
    tables: StaticTables,
    burst: int = 1,
) -> HandlerOut:
    """Run all three handlers over the pool under `exec_mask`.

    ``burst > 1`` (§Perf: burst emission) lets one EMIT event emit up to
    `burst` photons (ARRIVE times t + i*period) before scheduling its
    successor — valid because BB84 emission is feedback-free (paper obs.
    #5: sessions independent), deterministic because randomness is keyed
    by photon uid.  Collapses the serial EMIT-chain depth that sets the
    wave count per epoch.
    """
    cap = pool.capacity
    s = jnp.clip(pool.a0, 0, tables.n_sessions - 1)
    p = jnp.clip(pool.a1, 0, MAX_PHOTONS - 1)
    t = pool.time
    uid = _uid(s, p)
    is_local = _session_is_local(tables, router_owner, s)

    m_emit = exec_mask & (pool.kind == KIND_EMIT)
    m_arrive = exec_mask & (pool.kind == KIND_ARRIVE)
    m_class = exec_mask & (pool.kind == KIND_CLASSICAL)

    # ---------------- EMIT (bursted) ----------------
    ioff = jnp.arange(burst, dtype=jnp.int32)[None, :]      # (1, burst)
    pb = p[:, None] + ioff                                  # (cap, burst)
    sb = jnp.broadcast_to(s[:, None], (cap, burst))
    in_session = pb < tables.n_photons[s][:, None]
    m_emit_b = m_emit[:, None] & in_session
    uid_b = _uid(sb, jnp.clip(pb, 0, MAX_PHOTONS - 1))
    bit_b = rng.rand_bit(uid_b, rng.SALT_BIT)
    basis_b = rng.rand_bit(uid_b, rng.SALT_TX_BASIS)
    emit_t = t[:, None] + ioff * tables.period[s][:, None]

    # sender always records its preparation locally (used at CLASSICAL);
    # cross-shard sessions ALSO push the in-flight state to the global QSM.
    flat = lambda a: a.reshape(cap * burst)
    local_store = store_write(local_store, flat(sb), flat(pb), flat(bit_b),
                              flat(basis_b), flat(m_emit_b))

    qsm_op = jnp.where(m_emit_b & ~is_local[:, None], QSM_WRITE, 0)
    qsm_session = sb
    qsm_photon = pb
    qsm_payload = bit_b | (basis_b << 1)
    qsm_reply_time = jnp.zeros((cap, burst), jnp.int32)

    # staged block A: one ARRIVE per bursted photon
    stage_a = Staged(
        time=flat(emit_t + tables.q_delay[s][:, None]),
        kind=jnp.full((cap * burst,), KIND_ARRIVE, jnp.int32),
        dst=flat(jnp.broadcast_to(tables.dst[s][:, None], (cap, burst))),
        a0=flat(sb), a1=flat(jnp.clip(pb, 0, MAX_PHOTONS - 1)),
        a2=jnp.zeros((cap * burst,), jnp.int32),
        valid=flat(m_emit_b),
    )
    # staged slot B: next EMIT in the chain (if photons remain)
    n_emitted = jnp.sum(m_emit_b.astype(jnp.int32), axis=1)  # (cap,)
    p_next = p + n_emitted
    more = p_next < tables.n_photons[s]
    stage_b_emit = Staged(
        time=t + n_emitted * tables.period[s],
        kind=jnp.full((cap,), KIND_EMIT, jnp.int32),
        dst=tables.src[s],
        a0=s, a1=jnp.clip(p_next, 0, MAX_PHOTONS - 1),
        a2=jnp.zeros((cap,), jnp.int32),
        valid=m_emit & more,
    )
    # `done` is derived at report time (emitted >= n_photons); only counters
    # are updated here (scatter-add commutes -> wave batching is safe).
    sess = sess._replace(
        emitted=sess.emitted.at[s].add(jnp.where(m_emit, n_emitted, 0)))

    # ---------------- ARRIVE ----------------
    # Quantum-channel transmission + measurement: the paper's dominant event
    # type, served by the qchannel kernel (Pallas on TPU, oracle on CPU —
    # bit-identical integer math either way).
    from repro.kernels.qchannel.ops import transmit_measure

    sbit, sbasis, fresh = store_read(local_store, s, p)
    det_i, rx_basis, outcome = transmit_measure(
        uid, tables.loss_p[s], sbit, sbasis)
    detected = det_i == 1
    m_det = m_arrive & detected

    sess = sess._replace(
        detected=sess.detected.at[s].add(jnp.where(m_det, 1, 0)))

    m_local_meas = m_det & is_local
    stale = jnp.sum(jnp.where(m_local_meas & ~fresh, 1, 0))

    stage_b_classical = Staged(
        time=t + tables.c_delay[s],
        kind=jnp.full((cap,), KIND_CLASSICAL, jnp.int32),
        dst=tables.src[s],
        a0=s, a1=p,
        a2=pack_classical(outcome, rx_basis, jnp.ones((cap,), jnp.int32)),
        valid=m_local_meas,
    )
    # cross-shard measurement -> batched global-QSM request (column 0 of
    # the per-slot request block; EMIT bursts never share a slot with
    # ARRIVE, so the block is conflict-free)
    m_glob_meas = m_det & ~is_local
    qsm_op = qsm_op.at[:, 0].set(
        jnp.where(m_glob_meas, QSM_MEASURE, qsm_op[:, 0]))
    qsm_payload = qsm_payload.at[:, 0].set(
        jnp.where(m_glob_meas, rx_basis, qsm_payload[:, 0]))
    qsm_reply_time = qsm_reply_time.at[:, 0].set(
        jnp.where(m_glob_meas, t + tables.c_delay[s],
                  qsm_reply_time[:, 0]))

    # ---------------- CLASSICAL ----------------
    r_outcome, r_rx_basis, r_det = unpack_classical(pool.a2)
    my_bit, my_basis, my_fresh = store_read(local_store, s, p)
    sift = m_class & (r_det == 1) & (r_rx_basis == my_basis)
    stale = stale + jnp.sum(jnp.where(m_class & ~my_fresh, 1, 0))
    err = sift & (r_outcome != my_bit)
    # additive uint32 fold (commutative+associative -> scatter-add safe even
    # with several CLASSICALs for one session in a single wave)
    key_contrib = jnp.where(sift, rng.mix32((p << 1) | r_outcome,
                                            rng.SALT_BIT), jnp.uint32(0))
    sess = sess._replace(
        sifted=sess.sifted.at[s].add(jnp.where(sift, 1, 0)),
        errors=sess.errors.at[s].add(jnp.where(err, 1, 0)),
        key_hash=sess.key_hash.at[s].add(key_contrib),
    )

    # merge staged slot B (an event slot can be EMIT or ARRIVE, not both)
    stage_b = Staged(
        time=jnp.where(m_emit, stage_b_emit.time, stage_b_classical.time),
        kind=jnp.where(m_emit, stage_b_emit.kind, stage_b_classical.kind),
        dst=jnp.where(m_emit, stage_b_emit.dst, stage_b_classical.dst),
        a0=jnp.where(m_emit, stage_b_emit.a0, stage_b_classical.a0),
        a1=jnp.where(m_emit, stage_b_emit.a1, stage_b_classical.a1),
        a2=jnp.where(m_emit, stage_b_emit.a2, stage_b_classical.a2),
        valid=stage_b_emit.valid | stage_b_classical.valid,
    )
    staged = Staged(*[jnp.concatenate([a, b]) for a, b in
                      zip(stage_a, stage_b)])
    return HandlerOut(staged, sess, local_store,
                      flat(qsm_op), flat(qsm_session), flat(qsm_photon),
                      flat(qsm_payload), flat(qsm_reply_time), stale)
