"""Public simulator API: build state from a Network + partition, run epochs.

Execution modes:
  * ``vmap``  — S logical shards on one device (vmap(axis_name=...)); used
    for CPU tests/benchmarks.  Collective semantics are identical to the
    mesh path (same code, same axis primitives).
  * ``shard_map`` — S real mesh devices; used by the dry-run and on TPU.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import events as ev
from repro.core.qkd import MAX_PHOTONS, StaticTables
from repro.core.timeline import EngineConfig, run_epochs_scan
from repro.core.topology import Network, session_arrays
from repro.core.types import (
    KIND_EMIT, TIME_MAX, EventPool, Metrics, QsmStore, SessionState,
    ShardState,
)


@dataclasses.dataclass
class SimResults:
    emitted: np.ndarray
    detected: np.ndarray
    sifted: np.ndarray
    errors: np.ndarray
    key_hash: np.ndarray
    n_epochs: int
    metrics: Metrics          # stacked (S, n_epochs, ...) numpy pytree
    overflow: int
    stale_reads: int
    steals: list = dataclasses.field(default_factory=list)

    @property
    def qber(self) -> float:
        tot = self.sifted.sum()
        return float(self.errors.sum() / tot) if tot else 0.0

    def fingerprint(self) -> int:
        """Order-independent digest of the full simulation outcome."""
        with np.errstate(over="ignore"):
            h = np.uint64(0)
            for a in (self.emitted, self.detected, self.sifted, self.errors,
                      self.key_hash.astype(np.int64)):
                h = h * np.uint64(1099511628211) ^ np.uint64(
                    np.bitwise_xor.reduce(a.astype(np.uint64) + np.uint64(1)))
            return int(h)


def make_tables(net: Network) -> StaticTables:
    arr = session_arrays(net)
    assert len(net.sessions) > 0
    assert int(arr["n_photons"].max()) < MAX_PHOTONS
    return StaticTables(
        src=jnp.asarray(arr["src"]), dst=jnp.asarray(arr["dst"]),
        n_photons=jnp.asarray(arr["n_photons"]),
        period=jnp.asarray(arr["period"]),
        q_delay=jnp.asarray(arr["q_delay"]),
        c_delay=jnp.asarray(arr["c_delay"]),
        loss_p=jnp.asarray(arr["loss_p"]),
        start=jnp.asarray(arr["start"]),
        n_routers=net.n_routers,
        n_sessions=len(net.sessions),
    )


def auto_window(net: Network, margin: int = 8) -> int:
    """QSM window must cover the in-flight photon span of every session:
    a sender keeps emitting every `period` while the round trip
    (q_delay + c_delay) is outstanding, so the record for photon p must
    survive (q+c)/period subsequent writes."""
    arr = session_arrays(net)
    span = (arr["q_delay"].astype(np.int64) + arr["c_delay"]) \
        // np.maximum(arr["period"], 1) + margin
    w = int(span.max())
    return 1 << (w - 1).bit_length()  # next power of two


def auto_lookahead(net: Network, part: np.ndarray,
                   floor_ns: int = 1) -> int:
    """Min delay of any event that can cross shards (quantum & classical)."""
    arr = session_arrays(net)
    cross = part[arr["src"]] != part[arr["dst"]]
    if not cross.any():
        return int(TIME_MAX)
    return max(int(min(arr["q_delay"][cross].min(),
                       arr["c_delay"][cross].min())), floor_ns)


def build_state(net: Network, part: np.ndarray, cfg: EngineConfig,
                qsm_window: int = 128) -> ShardState:
    """Initial (S, ...) stacked per-shard state with one EMIT per session."""
    S = cfg.n_shards
    arr = session_arrays(net)
    n_sessions = len(net.sessions)
    cap = cfg.pool_cap

    time = np.full((S, cap), TIME_MAX, np.int32)
    kind = np.full((S, cap), -1, np.int32)
    dst = np.full((S, cap), -1, np.int32)
    a0 = np.full((S, cap), -1, np.int32)
    a1 = np.full((S, cap), -1, np.int32)
    a2 = np.zeros((S, cap), np.int32)
    valid = np.zeros((S, cap), bool)

    fill = np.zeros(S, np.int32)
    for s in range(n_sessions):
        owner = int(part[arr["src"][s]])
        i = fill[owner]
        if i >= cap:
            raise ValueError("pool_cap too small for initial events")
        time[owner, i] = arr["start"][s]
        kind[owner, i] = KIND_EMIT
        dst[owner, i] = arr["src"][s]
        a0[owner, i] = s
        a1[owner, i] = 0
        valid[owner, i] = True
        fill[owner] += 1

    pool = EventPool(
        time=jnp.asarray(time), kind=jnp.asarray(kind), dst=jnp.asarray(dst),
        a0=jnp.asarray(a0), a1=jnp.asarray(a1), a2=jnp.asarray(a2),
        valid=jnp.asarray(valid))

    zs = lambda dt: jnp.zeros((S, n_sessions), dt)
    sess = SessionState(
        emitted=zs(jnp.int32), detected=zs(jnp.int32), sifted=zs(jnp.int32),
        errors=zs(jnp.int32), key_hash=zs(jnp.uint32),
        done=zs(bool))

    def store():
        return QsmStore(
            bit=jnp.zeros((S, n_sessions, qsm_window), jnp.int32),
            basis=jnp.zeros((S, n_sessions, qsm_window), jnp.int32),
            stamp=jnp.full((S, n_sessions, qsm_window), -1, jnp.int32))

    router_owner = jnp.broadcast_to(jnp.asarray(part, jnp.int32),
                                    (S, net.n_routers))
    session_owner = jnp.broadcast_to(
        jnp.asarray(part[arr["src"]], jnp.int32), (S, n_sessions))
    return ShardState(
        pool=pool, sess=sess, local_store=store(), global_store=store(),
        router_owner=router_owner, session_owner=session_owner,
        overflow=jnp.zeros((S,), jnp.int32))


class Simulator:
    """Host-side driver around the jitted epoch scan."""

    def __init__(self, net: Network, part: np.ndarray, cfg: EngineConfig,
                 qsm_window: int | None = None,
                 mesh: Optional[Mesh] = None):
        assert cfg.n_shards == int(part.max()) + 1 or cfg.n_shards >= 1
        self.net, self.part, self.cfg = net, np.asarray(part), cfg
        self.tables = make_tables(net)
        la = cfg.lookahead_ns or auto_lookahead(net, self.part)
        self.lookahead = jnp.int32(min(la, int(TIME_MAX)))
        qsm_window = qsm_window or auto_window(net)
        self.state = build_state(net, self.part, cfg, qsm_window)
        self.mesh = mesh
        self._step = self._compile()

    def _compile(self):
        cfg, tables = self.cfg, self.tables

        def chunk(state, lookahead, n_epochs):
            return run_epochs_scan(state, tables, cfg, lookahead, n_epochs)

        if self.mesh is None:
            def stepper(state, lookahead, n_epochs: int):
                f = jax.vmap(partial(chunk, n_epochs=n_epochs),
                             axis_name=cfg.axis_name,
                             in_axes=(0, None))
                return f(state, lookahead)
            return jax.jit(stepper, static_argnums=2)

        mesh = self.mesh

        def per_shard(state_blk, lookahead, n_epochs: int):
            state = jax.tree.map(lambda x: x[0], state_blk)
            state, m = chunk(state, lookahead, n_epochs)
            expand = lambda x: x[None]
            return jax.tree.map(expand, state), jax.tree.map(expand, m)

        def stepper(state, lookahead, n_epochs: int):
            f = jax.shard_map(
                partial(per_shard, n_epochs=n_epochs), mesh=mesh,
                in_specs=(P(cfg.axis_name), P()),
                out_specs=(P(cfg.axis_name), P(cfg.axis_name)),
                check_vma=False)
            return f(state, lookahead)

        return jax.jit(stepper, static_argnums=2)

    def run(self, max_epochs: int = 100_000, chunk: int = 64,
            steal_every: int = 0, steal_threshold: float = 1.15
            ) -> SimResults:
        """Run to completion.  steal_every > 0 enables work stealing every
        `steal_every` chunks (chunk-boundary rebalancing, see
        workstealing.py)."""
        from repro.core import workstealing as ws

        state = self.state
        if self.mesh is not None:
            sh = NamedSharding(self.mesh, P(self.cfg.axis_name))
            state = jax.device_put(state, sh)
        chunks = []
        steals: list = []
        total = 0
        prev_emitted = np.asarray(state.sess.emitted).sum(0)
        prev_detected = np.asarray(state.sess.detected).sum(0)
        k = 0
        while total < max_epochs:
            state, m = self._step(state, self.lookahead, chunk)
            total += chunk
            k += 1
            chunks.append(jax.tree.map(np.asarray, m))
            if int(jnp.sum(state.pool.valid)) == 0:
                break
            if steal_every and k % steal_every == 0:
                em = np.asarray(state.sess.emitted).sum(0)
                det = np.asarray(state.sess.detected).sum(0)
                load = ws.session_load(
                    em - prev_emitted, det - prev_detected,
                    np.asarray(self.tables.src), np.asarray(self.tables.dst),
                    self.net.n_routers)
                prev_emitted, prev_detected = em, det
                owner = np.asarray(state.router_owner[0])
                moves, new_owner = ws.plan_moves(
                    load, owner, self.cfg.n_shards,
                    threshold=steal_threshold)
                if moves:
                    state, rep = ws.apply_moves(state, self.tables,
                                                new_owner)
                    steals.append(rep)
                    la = auto_lookahead(self.net, new_owner)
                    self.lookahead = jnp.int32(min(la, int(TIME_MAX)))
        self.state = state
        metrics = jax.tree.map(
            lambda *xs: np.concatenate(xs, axis=1), *chunks)
        sess = jax.tree.map(np.asarray, state.sess)
        res = SimResults(
            emitted=sess.emitted.sum(0), detected=sess.detected.sum(0),
            sifted=sess.sifted.sum(0), errors=sess.errors.sum(0),
            key_hash=(sess.key_hash.astype(np.uint64).sum(0)
                      & np.uint64(0xFFFFFFFF)).astype(np.uint32),
            n_epochs=total, metrics=metrics,
            overflow=int(np.asarray(state.overflow).sum()),
            stale_reads=int(metrics.stale_reads.sum()),
            steals=steals,
        )
        return res
