"""Event-pool operations: fixed-capacity vectorized insert/select.

The original SeQUeNCe keeps a Python heap and pops one event at a time; on
TPU we keep a flat struct-of-arrays pool in HBM and operate on it with masked
vector ops (select-all-in-window, segment-min per causal chain, rank-scatter
insertion).  See DESIGN.md §3.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import EventPool, Staged, KIND_NULL, TIME_MAX


def empty_pool(cap: int) -> EventPool:
    i32 = lambda fill: jnp.full((cap,), fill, dtype=jnp.int32)
    return EventPool(
        time=i32(TIME_MAX),
        kind=i32(KIND_NULL),
        dst=i32(-1),
        a0=i32(-1),
        a1=i32(-1),
        a2=i32(0),
        valid=jnp.zeros((cap,), dtype=bool),
    )


def empty_staged(n: int) -> Staged:
    i32 = lambda fill: jnp.full((n,), fill, dtype=jnp.int32)
    return Staged(
        time=i32(TIME_MAX), kind=i32(KIND_NULL), dst=i32(-1),
        a0=i32(-1), a1=i32(-1), a2=i32(0),
        valid=jnp.zeros((n,), dtype=bool),
    )


def next_time(pool: EventPool) -> jnp.ndarray:
    """Earliest timestamp among valid events (TIME_MAX if none)."""
    return jnp.min(jnp.where(pool.valid, pool.time, TIME_MAX))


def occupancy(pool: EventPool) -> jnp.ndarray:
    return jnp.sum(pool.valid.astype(jnp.int32))


def insert(pool: EventPool, staged: Staged):
    """Scatter staged (masked) events into free pool slots.

    Returns (pool, n_dropped).  Rank-scatter: the i-th live staged event goes
    to the i-th free slot; overflow events are dropped and counted (an
    overflow is a capacity-config bug, surfaced by the caller).
    """
    cap = pool.capacity
    free = ~pool.valid
    # position of the k-th free slot, padded with `cap` (out of range)
    free_slots = jnp.nonzero(free, size=cap, fill_value=cap)[0]
    n_free = jnp.sum(free.astype(jnp.int32))

    live = staged.valid
    rank = jnp.cumsum(live.astype(jnp.int32)) - 1          # rank among live
    ok = live & (rank < n_free)
    slot = jnp.where(ok, free_slots[jnp.clip(rank, 0, cap - 1)], cap)
    n_dropped = jnp.sum((live & ~ok).astype(jnp.int32))

    def scat(dst_arr, src_arr, fill_ok):
        # drop-out-of-range scatter: slot == cap rows are discarded
        return dst_arr.at[slot].set(
            jnp.where(fill_ok, src_arr, dst_arr[jnp.clip(slot, 0, cap - 1)]),
            mode="drop",
        )

    new = EventPool(
        time=scat(pool.time, staged.time, ok),
        kind=scat(pool.kind, staged.kind, ok),
        dst=scat(pool.dst, staged.dst, ok),
        a0=scat(pool.a0, staged.a0, ok),
        a1=scat(pool.a1, staged.a1, ok),
        a2=scat(pool.a2, staged.a2, ok),
        valid=pool.valid.at[slot].set(ok, mode="drop"),
    )
    return new, n_dropped


def invalidate(pool: EventPool, mask: jnp.ndarray) -> EventPool:
    """Mark events under `mask` as consumed."""
    return pool._replace(
        valid=pool.valid & ~mask,
        time=jnp.where(mask, TIME_MAX, pool.time),
        kind=jnp.where(mask, KIND_NULL, pool.kind),
    )


def concat_staged(*parts: Staged) -> Staged:
    return Staged(*[jnp.concatenate(fs) for fs in zip(*parts)])


def pool_as_staged(pool: EventPool, mask: jnp.ndarray) -> Staged:
    """View (masked) pool entries as a staging buffer (for outbox routing)."""
    return Staged(
        time=pool.time, kind=pool.kind, dst=pool.dst,
        a0=pool.a0, a1=pool.a1, a2=pool.a2, valid=mask,
    )
