"""Counted append buffers + generic cross-shard record routing.

`route_records` is the TPU-native replacement for MPI point-to-point: records
carrying a destination-shard id are sorted by destination, rank-scattered
into a (n_shards, per_dest_cap) send buffer and exchanged with ONE tiled
all_to_all.  It is reused by the event outbox, the hashed-QSM request/reply
paths, and work-stealing state migration.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax


def append(buf: Any, count: jnp.ndarray, new: Any, new_valid: jnp.ndarray,
           cap: int):
    """Append masked records (pytree of [N] arrays) into a counted buffer
    (pytree of [cap] arrays).  Returns (buf, count, n_dropped)."""
    live = new_valid
    rank = jnp.cumsum(live.astype(jnp.int32)) - 1
    slot = jnp.where(live, count + rank, cap)
    ok = live & (slot < cap)
    n_added = jnp.sum(ok.astype(jnp.int32))
    n_dropped = jnp.sum(live.astype(jnp.int32)) - n_added

    def scat(dst, src):
        return dst.at[slot].set(
            jnp.where(ok, src, dst[jnp.clip(slot, 0, cap - 1)]), mode="drop")

    buf = jax.tree.map(scat, buf, new)
    return buf, count + n_added, n_dropped


def route_records(fields: Any, dest_shard: jnp.ndarray, valid: jnp.ndarray,
                  n_shards: int, per_dest_cap: int, axis_name: str):
    """Exchange records between shards.

    fields: pytree of [N] arrays (per-shard view inside vmap/shard_map).
    Returns (recv_fields pytree of [n_shards*per_dest_cap], recv_valid,
    n_sent, n_dropped).
    """
    n = valid.shape[0]
    key = jnp.where(valid, dest_shard, n_shards)
    order = jnp.argsort(key)  # stable
    sd = key[order]
    idx = jnp.arange(n, dtype=jnp.int32)
    first = jnp.searchsorted(sd, sd, side="left").astype(jnp.int32)
    rank = idx - first
    ok = (sd < n_shards) & (rank < per_dest_cap)
    slot = jnp.where(ok, sd * per_dest_cap + rank, n_shards * per_dest_cap)
    n_sent = jnp.sum(ok.astype(jnp.int32))
    n_dropped = jnp.sum(valid.astype(jnp.int32)) - n_sent

    size = n_shards * per_dest_cap

    def scat(f):
        fs = f[order]
        buf = jnp.zeros((size,), f.dtype)
        return buf.at[slot].set(jnp.where(ok, fs, jnp.zeros((), f.dtype)),
                                mode="drop")

    send = jax.tree.map(scat, fields)
    send_valid = jnp.zeros((size,), bool).at[slot].set(ok, mode="drop")

    # tiled all_to_all on the flat buffer: send rows [i*K:(i+1)*K] go to
    # shard i; received segment j holds what source shard j addressed to us.
    a2a = lambda x: lax.all_to_all(x, axis_name, split_axis=0,
                                   concat_axis=0, tiled=True)
    recv = jax.tree.map(a2a, send)
    recv_valid = a2a(send_valid)
    return recv, recv_valid, n_sent, n_dropped
