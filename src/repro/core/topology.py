"""Topology + workload generators (host side, numpy/networkx).

Reproduces the paper's two experiment setups:
  * linear — 1024 routers in a chain, QKD sessions between adjacent pairs
    (trusted-node relay), evenly distributed workload (paper obs. #3).
  * autonomous-system (AS) — hub-and-spoke ASes joined by a core mesh, a
    "more varied workload spread across the network" with hub hotspots,
    which is what produces the straggler pathology of Figs 4–7.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Channel:
    u: int
    v: int
    delay_ns: int  # quantum propagation delay


@dataclasses.dataclass(frozen=True)
class Session:
    """One QKD session: src prepares photons, dst measures them."""

    src: int
    dst: int
    n_photons: int
    period_ns: int
    q_delay_ns: int   # quantum channel propagation delay src->dst
    c_delay_ns: int   # classical channel delay dst->src (> quantum, obs. #4)
    loss_p: float     # photon loss probability
    start_ns: int = 0


@dataclasses.dataclass(frozen=True)
class Network:
    n_routers: int
    channels: List[Channel]
    sessions: List[Session]
    name: str = "net"

    def adjacency(self) -> np.ndarray:
        a = np.zeros((self.n_routers, self.n_routers), dtype=bool)
        for c in self.channels:
            a[c.u, c.v] = a[c.v, c.u] = True
        return a


# ---------------------------------------------------------------------------
# Linear topology (paper §III-B)
# ---------------------------------------------------------------------------
def linear_network(
    n_routers: int = 1024,
    n_photons: int = 256,
    period_ns: int = 1_000,
    hop_delay_ns: int = 25_000,
    classical_mult: float = 2.0,
    loss_p: float = 0.1,
) -> Network:
    channels = [
        Channel(i, i + 1, hop_delay_ns) for i in range(n_routers - 1)
    ]
    sessions = [
        Session(
            src=i, dst=i + 1, n_photons=n_photons, period_ns=period_ns,
            q_delay_ns=hop_delay_ns,
            c_delay_ns=int(hop_delay_ns * classical_mult),
            loss_p=loss_p,
        )
        for i in range(n_routers - 1)
    ]
    return Network(n_routers, channels, sessions, name="linear")


# ---------------------------------------------------------------------------
# Autonomous-system topology (paper §III-C)
# ---------------------------------------------------------------------------
def as_network(
    n_routers: int = 1024,
    n_as: int = 32,
    seed: int = 0,
    n_photons: int = 256,
    period_ns: int = 1_000,
    hop_delay_ns: int = 25_000,
    core_delay_ns: int = 50_000,
    classical_mult: float = 2.0,
    loss_p: float = 0.1,
    hotspot_frac: float = 0.25,
    hotspot_boost: int = 6,
) -> Network:
    """AS graph: `n_as` hub-and-spoke clusters; hubs form a ring + chords.

    Sessions run between random leaf pairs, with a `hotspot_frac` subset of
    ASes receiving `hotspot_boost`x as many sessions — the imbalance that
    reproduces the paper's straggler (Fig 7: one process dominates).
    """
    rng = np.random.default_rng(seed)
    sizes = rng.dirichlet(np.ones(n_as) * 4.0) * (n_routers - n_as)
    sizes = np.maximum(sizes.astype(int), 1)
    while sizes.sum() < n_routers - n_as:
        sizes[rng.integers(n_as)] += 1
    while sizes.sum() > n_routers - n_as:
        sizes[np.argmax(sizes)] -= 1

    channels: List[Channel] = []
    hubs: List[int] = []
    members: List[List[int]] = []
    nxt = 0
    for a in range(n_as):
        hub = nxt
        hubs.append(hub)
        leaf_lo = nxt + 1
        leaves = list(range(leaf_lo, leaf_lo + sizes[a]))
        members.append([hub] + leaves)
        for leaf in leaves:
            channels.append(Channel(hub, leaf, hop_delay_ns))
        nxt = leaf_lo + sizes[a]
    assert nxt == n_routers, (nxt, n_routers)

    # core: ring over hubs + random chords
    for a in range(n_as):
        channels.append(Channel(hubs[a], hubs[(a + 1) % n_as], core_delay_ns))
    for _ in range(n_as // 2):
        a, b = rng.choice(n_as, size=2, replace=False)
        channels.append(Channel(hubs[a], hubs[b], core_delay_ns))

    # sessions: leaf -> leaf inside an AS, through-hub pairs across ASes
    hot = set(rng.choice(n_as, size=max(1, int(n_as * hotspot_frac)),
                         replace=False).tolist())
    sessions: List[Session] = []
    for a in range(n_as):
        weight = hotspot_boost if a in hot else 1
        leaves = members[a][1:] or members[a]
        for _ in range(weight * max(1, len(leaves) // 2)):
            if len(leaves) >= 2 and rng.random() < 0.7:
                # intra-AS session (leaf-hub-leaf, 2 hops)
                u, v = rng.choice(leaves, size=2, replace=False)
                qd = 2 * hop_delay_ns
            else:
                # inter-AS session via the core (leaf-hub-core-hub-leaf)
                b = int(rng.integers(n_as))
                other = members[b][1:] or members[b]
                u = int(rng.choice(leaves))
                v = int(rng.choice(other))
                if u == v:
                    continue
                qd = 2 * hop_delay_ns + core_delay_ns
            sessions.append(Session(
                src=int(u), dst=int(v), n_photons=n_photons,
                period_ns=period_ns, q_delay_ns=qd,
                c_delay_ns=int(qd * classical_mult), loss_p=loss_p,
            ))
    return Network(n_routers, channels, sessions, name="as")


def session_arrays(net: Network) -> dict:
    """Static per-session parameter table as numpy arrays."""
    s = net.sessions
    return dict(
        src=np.array([x.src for x in s], np.int32),
        dst=np.array([x.dst for x in s], np.int32),
        n_photons=np.array([x.n_photons for x in s], np.int32),
        period=np.array([x.period_ns for x in s], np.int32),
        q_delay=np.array([x.q_delay_ns for x in s], np.int32),
        c_delay=np.array([x.c_delay_ns for x in s], np.int32),
        loss_p=np.array([x.loss_p for x in s], np.float32),
        start=np.array([x.start_ns for x in s], np.int32),
    )
