"""Dynamic work stealing — the paper's §IV proposal, implemented.

The paper concludes that static partitioning cannot fix the straggler
because "the unit of parallelism is the simulated router and that is
precisely where the problem is", and suggests dynamic work sharing/stealing.

We implement chunk-boundary rebalancing: every K epochs the driver reads the
per-shard load observed in the last chunk (REAL event counts, not a model),
greedily moves the hottest routers from overloaded shards to underloaded
ones, and migrates all affected state (pool events, QSM rows, session
counters follow their owner by construction — they are globally indexed and
owner-written, so only the ownership map and pool entries move).  On real
hardware the identical mechanism runs host-coordinated between jitted chunks
(the same place checkpointing runs); migration traffic is billed in the cost
model via bytes moved.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.qkd import StaticTables
from repro.core.types import ShardState


@dataclasses.dataclass
class StealReport:
    moved_routers: int
    moved_events: int
    bytes_moved: int
    imbalance_before: float
    imbalance_after: float


def session_load(sess_emitted_delta: np.ndarray,
                 sess_detected_delta: np.ndarray,
                 src: np.ndarray, dst: np.ndarray,
                 n_routers: int) -> np.ndarray:
    """Per-router observed load from per-session counter deltas."""
    load = np.zeros(n_routers, dtype=np.int64)
    np.add.at(load, src, sess_emitted_delta)
    np.add.at(load, dst, sess_detected_delta)
    return load


def plan_moves(router_load: np.ndarray, owner: np.ndarray, n_shards: int,
               max_moves: int = 64, threshold: float = 1.15):
    """Greedy: move hottest routers from the hottest shard to the coldest.

    Returns list of (router, new_shard)."""
    owner = owner.copy()
    per = np.zeros(n_shards, dtype=np.int64)
    np.add.at(per, owner, router_load)
    moves = []
    for _ in range(max_moves):
        hot = int(per.argmax())
        cold = int(per.argmin())
        mean = per.mean() if per.mean() > 0 else 1.0
        if per[hot] <= threshold * mean or hot == cold:
            break
        mine = np.where(owner == hot)[0]
        if len(mine) <= 1:
            break
        # biggest router that still fits under the mean at the target
        cand = mine[np.argsort(-router_load[mine])]
        moved = False
        for r in cand:
            lr = router_load[r]
            if lr == 0:
                break
            if per[cold] + lr < per[hot]:
                owner[r] = cold
                per[hot] -= lr
                per[cold] += lr
                moves.append((int(r), cold))
                moved = True
                break
        if not moved:
            break
    return moves, owner


def apply_moves(state: ShardState, tables: StaticTables,
                new_owner: np.ndarray) -> tuple[ShardState, StealReport]:
    """Migrate state to match `new_owner` (host-side, numpy)."""
    import jax.numpy as jnp

    S = state.pool.time.shape[0]
    old_owner = np.asarray(state.router_owner[0])
    changed = np.where(old_owner != new_owner)[0]

    pool = {f: np.asarray(getattr(state.pool, f)).copy()
            for f in state.pool._fields}
    src = np.asarray(tables.src)
    dst_t = np.asarray(tables.dst)

    moved_events = 0
    bytes_moved = 0
    if len(changed):
        # --- migrate pool events whose dst router changed owner ---
        for sh in range(S):
            v = pool["valid"][sh]
            ev_dst = pool["dst"][sh]
            sel = v & np.isin(ev_dst, changed)
            idxs = np.where(sel)[0]
            for i in idxs:
                tgt = int(new_owner[ev_dst[i]])
                if tgt == sh:
                    continue
                free = np.where(~pool["valid"][tgt])[0]
                if len(free) == 0:
                    raise RuntimeError("pool overflow during migration")
                j = free[0]
                for f in state.pool._fields:
                    pool[f][tgt, j] = pool[f][sh, i]
                pool["valid"][sh, i] = False
                pool["time"][sh, i] = np.iinfo(np.int32).max // 2
                pool["kind"][sh, i] = -1
                moved_events += 1
        bytes_moved += moved_events * 7 * 4

        # --- migrate session rows (stores + counters) ---
        sess_arrays = {f: np.asarray(getattr(state.sess, f)).copy()
                       for f in state.sess._fields}
        ls = {f: np.asarray(getattr(state.local_store, f)).copy()
              for f in state.local_store._fields}
        gs = {f: np.asarray(getattr(state.global_store, f)).copy()
              for f in state.global_store._fields}
        touched = np.where(np.isin(src, changed) | np.isin(dst_t, changed))[0]
        for s_id in touched:
            o_src_old, o_src_new = int(old_owner[src[s_id]]), int(
                new_owner[src[s_id]])
            o_dst_old, o_dst_new = int(old_owner[dst_t[s_id]]), int(
                new_owner[dst_t[s_id]])
            # sender-owned counters follow owner(src)
            if o_src_old != o_src_new:
                for f in ("emitted", "sifted", "errors", "key_hash"):
                    sess_arrays[f][o_src_new, s_id] += \
                        sess_arrays[f][o_src_old, s_id]
                    sess_arrays[f][o_src_old, s_id] = 0
                bytes_moved += 16
            if o_dst_old != o_dst_new:
                sess_arrays["detected"][o_dst_new, s_id] += \
                    sess_arrays["detected"][o_dst_old, s_id]
                sess_arrays["detected"][o_dst_old, s_id] = 0
                bytes_moved += 4
            # local-store row must exist wherever sender or receiver lives
            donors = [sh for sh in (o_src_old, o_dst_old)
                      if ls["stamp"][sh, s_id].max() >= 0]
            if donors:
                don = donors[0]
                for tgt in {o_src_new, o_dst_new}:
                    if tgt != don:
                        for f in ls:
                            ls[f][tgt, s_id] = ls[f][don, s_id]
                        bytes_moved += ls["bit"].shape[-1] * 12
                # a session that becomes (or stays) cross-shard must have
                # its in-flight photon records visible to the global QSM:
                # refresh every shard's global-store row from the sender's
                # local record (identical values were written at EMIT, so
                # this is a no-op for already-cross sessions in gathered
                # mode and supplies the row for newly-cross ones).
                for f in gs:
                    gs[f][:, s_id] = ls[f][don, s_id]
                bytes_moved += ls["bit"].shape[-1] * 12

        state = state._replace(
            sess=type(state.sess)(**{f: jnp.asarray(a) for f, a in
                                     sess_arrays.items()}),
            local_store=type(state.local_store)(
                **{f: jnp.asarray(a) for f, a in ls.items()}),
            global_store=type(state.global_store)(
                **{f: jnp.asarray(a) for f, a in gs.items()}),
        )

    per_old = np.zeros(S)
    per_new = np.zeros(S)
    # imbalance on router count as a cheap proxy for the report
    np.add.at(per_old, old_owner, 1)
    np.add.at(per_new, new_owner, 1)

    state = state._replace(
        pool=type(state.pool)(**{f: jnp.asarray(a) for f, a in pool.items()}),
        router_owner=jnp.broadcast_to(
            jnp.asarray(new_owner, jnp.int32),
            state.router_owner.shape),
        session_owner=jnp.broadcast_to(
            jnp.asarray(new_owner[src], jnp.int32),
            state.session_owner.shape),
    )
    rep = StealReport(
        moved_routers=len(changed), moved_events=moved_events,
        bytes_moved=bytes_moved,
        imbalance_before=float(per_old.max() / max(per_old.mean(), 1)),
        imbalance_after=float(per_new.max() / max(per_new.mean(), 1)),
    )
    return state, rep
