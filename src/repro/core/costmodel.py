"""Calibrated performance model for multi-node scaling projections.

This container exposes one CPU device, so wall-clock scaling beyond a
handful of host devices cannot be *measured*; the paper's figures are
reproduced by combining
  * REAL per-shard event/wave counts from actual simulation runs (the
    workload distribution is exact — it is the straggler), with
  * a calibrated linear cost model for compute and an alpha-beta model for
    communication.

`calibrate()` measures per-event and per-wave costs of the vectorized engine
on this host.  Hardware presets translate collective sizes into seconds.
Every benchmark CSV labels modeled columns explicitly.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.types import Metrics

EVENT_BYTES = 7 * 4          # one event record (7 int32 fields)
QSM_REQ_BYTES = 5 * 4        # one QSM request/reply


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Alpha-beta communication constants + serial server costs."""

    name: str
    alpha_sync_s: float       # latency of a barrier/allreduce hop
    link_bw_Bps: float        # per-link bandwidth for bulk exchange
    server_req_s: float       # global-QSM server per-request service time
    server_alpha_s: float     # global-QSM per-batch overhead (per client)

    def sync_time(self, n_shards: int) -> float:
        return self.alpha_sync_s * max(1, int(np.log2(max(n_shards, 2))))

    def exchange_time(self, n_bytes: float, n_shards: int) -> float:
        if n_shards <= 1:
            return 0.0
        return self.alpha_sync_s + n_bytes / self.link_bw_Bps


# Frontier-like: HPE Slingshot 25 GB/s/NIC, ~5 us MPI latency; the Python
# QSM server of the paper services requests at ~10 us/req over sockets.
FRONTIER = HardwareModel("frontier", alpha_sync_s=5e-6, link_bw_Bps=25e9,
                         server_req_s=10e-6, server_alpha_s=50e-6)
# TPU v5e pod: ~1 us ICI collective latency, 50 GB/s/link, QSM is compiled
# code on-chip (no socket/server penalty).
TPU_POD = HardwareModel("tpu_v5e", alpha_sync_s=1e-6, link_bw_Bps=50e9,
                        server_req_s=2e-7, server_alpha_s=2e-6)


@dataclasses.dataclass(frozen=True)
class ComputeModel:
    """t_busy = c_epoch + c_wave * waves + c_event * events  (seconds)."""

    c_epoch: float
    c_wave: float
    c_event: float

    def busy(self, waves: np.ndarray, events: np.ndarray) -> np.ndarray:
        return self.c_epoch + self.c_wave * waves + self.c_event * events


# Sequential-SeQUeNCe-like per-event cost (Python heap + handler ~ 20 us);
# used when projecting the paper's own numbers.
SEQUENCE_PY = ComputeModel(c_epoch=50e-6, c_wave=0.0, c_event=20e-6)
# Our vectorized engine: calibrated on this host by calibrate().
DEFAULT_VECTOR = ComputeModel(c_epoch=20e-6, c_wave=5e-6, c_event=0.05e-6)


def calibrate(runner=None) -> ComputeModel:
    """Fit (c_epoch, c_wave, c_event) from real runs on this host.

    `runner(n_routers, n_photons)` must run a 1-shard sim and return
    (wall_seconds, total_epochs, total_waves, total_events); default uses a
    linear network.
    """
    if runner is None:
        from repro.core.partition import make_partition
        from repro.core.simulator import Simulator
        from repro.core.timeline import EngineConfig
        from repro.core.topology import linear_network

        def runner(n_routers, n_photons):
            net = linear_network(n_routers=n_routers, n_photons=n_photons,
                                 loss_p=0.1)
            cfg = EngineConfig(n_shards=1, pool_cap=4 * n_routers,
                               qsm_cap=128, outbox_cap=128, route_cap=32)
            sim = Simulator(net, make_partition(net, 1), cfg)
            sim.run(max_epochs=8, chunk=8)  # warmup/compile
            sim2 = Simulator(net, make_partition(net, 1), cfg)
            t0 = time.perf_counter()
            r = sim2.run(max_epochs=4096, chunk=256)
            wall = time.perf_counter() - t0
            m = r.metrics
            return (wall, r.n_epochs, int(m.n_waves.sum()),
                    int(m.events_by_kind.sum()))

    rows, ys = [], []
    for n_routers, n_photons in ((16, 32), (64, 64), (128, 128)):
        wall, ep, waves, events = runner(n_routers, n_photons)
        rows.append([ep, waves, events])
        ys.append(wall)
    A = np.asarray(rows, float)
    y = np.asarray(ys, float)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    coef = np.maximum(coef, [1e-7, 1e-7, 1e-9])
    return ComputeModel(c_epoch=float(coef[0]), c_wave=float(coef[1]),
                        c_event=float(coef[2]))


@dataclasses.dataclass
class EpochBreakdown:
    """Per-shard, per-epoch modeled times (the paper's Figs 3/5/6 data)."""

    compute: np.ndarray   # (S, E) busy time
    wait: np.ndarray      # (S, E) straggler wait (barrier-split, Fig 5)
    comm: np.ndarray      # (S, E) sync + outbox exchange
    qsm: np.ndarray       # (S, E) global-QSM service ("socket" in Fig 3)

    @property
    def epoch_wall(self) -> np.ndarray:  # (E,)
        return (self.compute + self.wait).max(axis=0) + \
            self.comm.max(axis=0) + self.qsm.max(axis=0)

    @property
    def total_wall(self) -> float:
        return float(self.epoch_wall.sum())

    def averages(self) -> dict:
        """Per-process averages as plotted by the paper."""
        return dict(
            compute=float(self.compute.sum(axis=1).mean()),
            wait=float(self.wait.sum(axis=1).mean()),
            comm=float(self.comm.sum(axis=1).mean()),
            qsm=float(self.qsm.sum(axis=1).mean()),
        )


def breakdown(metrics: Metrics, n_shards: int, hw: HardwareModel,
              cm: ComputeModel, qsm_mode: str = "gathered",
              merge_wait_into_compute: bool = False) -> EpochBreakdown:
    """Convert per-epoch Metrics (S, E, ...) into modeled times.

    merge_wait_into_compute reproduces the paper's Fig 6 redefinition
    (wait counted as compute, "which more accurately portrays the
    limitations of its scalability").
    """
    waves = np.asarray(metrics.n_waves, dtype=float)          # (S, E)
    events = np.asarray(metrics.events_by_kind, float).sum(-1)  # (S, E)
    outbox = np.asarray(metrics.outbox_sent, float)           # (S, E)
    qsm_req = np.asarray(metrics.qsm_requests, float)         # (S, E)

    busy = cm.busy(waves, events)                             # (S, E)
    wait = busy.max(axis=0, keepdims=True) - busy             # (S, E)

    sync = hw.sync_time(n_shards)
    comm = sync + np.vectorize(
        lambda b: hw.exchange_time(b * EVENT_BYTES, n_shards))(outbox)

    if qsm_mode == "gathered":
        # single server: every shard waits for the full batch
        total_req = qsm_req.sum(axis=0, keepdims=True)        # (1, E)
        q = hw.server_alpha_s * (total_req > 0) + \
            hw.server_req_s * total_req
        q = np.broadcast_to(q, busy.shape).copy()
    else:
        # hash-partitioned: each shard serves ~1/S of the batch, plus an
        # all_to_all each way
        per = qsm_req.sum(axis=0, keepdims=True) / max(n_shards, 1)
        q = hw.server_req_s * per + 2 * np.vectorize(
            lambda b: hw.exchange_time(b * QSM_REQ_BYTES, n_shards))(per)
        q = np.broadcast_to(q, busy.shape).copy()

    if merge_wait_into_compute:
        busy = busy + wait
        wait = np.zeros_like(wait)
    return EpochBreakdown(compute=busy, wait=wait, comm=comm, qsm=q)
