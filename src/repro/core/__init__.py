"""repro.core — TPU-native parallel discrete-event quantum network simulator.

The paper's system (parallel SeQUeNCe) rebuilt as a vectorized,
collective-synchronized PDES in JAX.  See DESIGN.md.
"""
from repro.core.costmodel import (
    FRONTIER, TPU_POD, ComputeModel, EpochBreakdown, HardwareModel,
    breakdown, calibrate,
)
from repro.core.partition import (
    cut_channels, cut_sessions, load_imbalance, make_partition,
)
from repro.core.simulator import (
    SimResults, Simulator, auto_lookahead, auto_window, build_state,
    make_tables,
)
from repro.core.timeline import EngineConfig
from repro.core.topology import Network, Session, as_network, linear_network

__all__ = [
    "FRONTIER", "TPU_POD", "ComputeModel", "EpochBreakdown", "HardwareModel",
    "breakdown", "calibrate", "cut_channels", "cut_sessions",
    "load_imbalance", "make_partition", "SimResults", "Simulator",
    "auto_lookahead", "auto_window", "build_state", "make_tables",
    "EngineConfig", "Network", "Session", "as_network", "linear_network",
]
