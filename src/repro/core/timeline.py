"""Conservative-PDES epoch loop, vectorized per shard.

One epoch (mirrors parallel SeQUeNCe's synchronisation epochs):
  1. lookahead sync: epoch_end = all-reduce-min(next event ts) + lookahead
     (lookahead = min cross-shard channel delay, quantum AND classical —
     guarantees any cross-shard event generated inside the epoch lands at or
     after epoch_end, i.e. causality).
  2. wave loop: repeatedly execute, in parallel, every in-window event whose
     per-chain order allows it (EMIT chains: earliest per session;
     ARRIVE/CLASSICAL commute).  Generated local events join the pool and
     may themselves run later in the same epoch.  Cross-shard events are
     staged in the outbox; cross-shard quantum-state ops are staged as QSM
     requests (SeQUeNCe batches its socket requests the same way).
  3. QSM phase: process the batched requests (gathered or hashed mode),
     insert locally-addressed reply events.
  4. outbox exchange: one all_to_all delivers cross-shard events.
  5. instrumentation: per-shard counters for the cost model / Figs 3-7.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import events as ev
from repro.core import qsm as qsm_mod
from repro.core.buffering import append, route_records
from repro.core.qkd import StaticTables, handle_all
from repro.core.types import (
    KIND_EMIT, N_KINDS, TIME_MAX, EventPool, Metrics, ShardState, Staged,
)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_shards: int
    pool_cap: int = 4096
    qsm_cap: int = 2048          # per-epoch QSM request staging
    outbox_cap: int = 2048       # per-epoch cross-shard event staging
    route_cap: int = 256         # per-destination all_to_all slots
    lookahead_ns: int = 0        # 0 -> auto (min cross-shard delay)
    qsm_mode: str = qsm_mod.GATHERED
    axis_name: str = "shards"
    max_waves: int = 100_000
    burst_emit: bool = False     # beyond-paper: emit whole epoch window


def _exec_mask(pool: EventPool, epoch_end, n_sessions: int):
    """Which events may run this wave (causal per-chain gating)."""
    in_win = pool.valid & (pool.time < epoch_end)
    m_emit = in_win & (pool.kind == KIND_EMIT)
    # EMIT chains: at most one live EMIT per session exists (each EMIT
    # schedules its successor), so the per-session min gate is exact.
    s = jnp.clip(pool.a0, 0, n_sessions - 1)
    seg = jnp.full((n_sessions,), TIME_MAX, jnp.int32).at[s].min(
        jnp.where(m_emit, pool.time, TIME_MAX))
    emit_ok = m_emit & (pool.time <= seg[s])
    return in_win & ((pool.kind != KIND_EMIT) | emit_ok), in_win


def run_epoch(
    state: ShardState,
    tables: StaticTables,
    cfg: EngineConfig,
    lookahead: jnp.ndarray,
) -> Tuple[ShardState, Metrics]:
    axis = cfg.axis_name
    n_shards = cfg.n_shards
    me = lax.axis_index(axis)

    # ---- 1. lookahead synchronization ----
    nt = ev.next_time(state.pool)
    global_next = lax.pmin(nt, axis)
    # saturating add: TIME_MAX stays TIME_MAX
    epoch_end = global_next + jnp.minimum(lookahead, TIME_MAX - global_next)

    qcap, ocap = cfg.qsm_cap, cfg.outbox_cap
    qsm_buf = dict(
        op=jnp.zeros((qcap,), jnp.int32),
        session=jnp.zeros((qcap,), jnp.int32),
        photon=jnp.zeros((qcap,), jnp.int32),
        payload=jnp.zeros((qcap,), jnp.int32),
        reply_time=jnp.zeros((qcap,), jnp.int32),
    )
    outbox = ev.empty_staged(ocap)

    def wave_cond(carry):
        (pool, *_rest), counters = carry
        _, in_win = _exec_mask(pool, epoch_end, tables.n_sessions)
        return jnp.any(in_win) & (counters["waves"] < cfg.max_waves)

    burst = 8 if cfg.burst_emit else 1

    def wave_body(carry):
        (pool, sess, lstore, qbuf, qcount, obox, ocount), c = carry
        exec_mask, _ = _exec_mask(pool, epoch_end, tables.n_sessions)
        out = handle_all(pool, exec_mask, sess, lstore,
                         state.router_owner, tables, burst=burst)
        kind_before = pool.kind
        pool = ev.invalidate(pool, exec_mask)

        # split staged events into local-destination vs cross-shard
        dest = state.router_owner[
            jnp.clip(out.staged.dst, 0, tables.n_routers - 1)]
        local_valid = out.staged.valid & (dest == me)
        remote_valid = out.staged.valid & (dest != me)
        pool, d1 = ev.insert(pool, out.staged._replace(valid=local_valid))
        obox, ocount, d2 = append(
            obox._replace(valid=obox.valid),
            ocount,
            out.staged._replace(valid=remote_valid),
            remote_valid, ocap)
        # NOTE: append writes all fields incl. `valid`; patch it to be the
        # occupancy mask of the buffer.
        obox = obox._replace(
            valid=(jnp.arange(ocap) < ocount))

        qreq_valid = out.qsm_op != 0
        qnew = dict(op=out.qsm_op,
                    session=jnp.clip(out.qsm_session, 0,
                                     tables.n_sessions - 1),
                    photon=jnp.clip(out.qsm_photon, 0, 1 << 16),
                    payload=out.qsm_payload,
                    reply_time=out.qsm_reply_time)
        qbuf, qcount, d3 = append(qbuf, qcount, qnew, qreq_valid, qcap)

        kinds = jax.nn.one_hot(
            jnp.clip(kind_before, 0, N_KINDS - 1), N_KINDS, dtype=jnp.int32)
        c = dict(
            waves=c["waves"] + 1,
            events=c["events"] + jnp.sum(
                jnp.where(exec_mask[:, None], kinds, 0), axis=0),
            dropped=c["dropped"] + d1 + d2 + d3,
            stale=c["stale"] + out.stale,
            pool_high=jnp.maximum(c["pool_high"], ev.occupancy(pool)),
        )
        return (pool, out.sess, out.local_store, qbuf, qcount, obox,
                ocount), c

    counters0 = dict(
        waves=jnp.int32(0),
        events=jnp.zeros((N_KINDS,), jnp.int32),
        dropped=jnp.int32(0),
        stale=jnp.int32(0),
        pool_high=ev.occupancy(state.pool),
    )
    carry0 = ((state.pool, state.sess, state.local_store, qsm_buf,
               jnp.int32(0), outbox, jnp.int32(0)), counters0)
    (pool, sess, lstore, qbuf, qcount, obox, ocount), counters = \
        lax.while_loop(wave_cond, wave_body, carry0)

    # ---- 3. QSM phase ----
    qout = qsm_mod.qsm_phase(
        qbuf["op"], qbuf["session"], qbuf["photon"], qbuf["payload"],
        qbuf["reply_time"], qcount, state.global_store, tables,
        state.router_owner, cfg.qsm_mode, n_shards, axis, cfg.route_cap)
    pool, d4 = ev.insert(pool, qout.replies)

    # ---- 4. outbox exchange ----
    ob_fields = dict(time=obox.time, kind=obox.kind, dst=obox.dst,
                     a0=obox.a0, a1=obox.a1, a2=obox.a2)
    ob_dest = state.router_owner[jnp.clip(obox.dst, 0,
                                          tables.n_routers - 1)]
    recv, rvalid, n_sent, d5 = route_records(
        ob_fields, ob_dest, obox.valid, n_shards, cfg.route_cap, axis)
    incoming = Staged(time=recv["time"], kind=recv["kind"], dst=recv["dst"],
                      a0=recv["a0"], a1=recv["a1"], a2=recv["a2"],
                      valid=rvalid)
    pool, d6 = ev.insert(pool, incoming)

    new_state = state._replace(
        pool=pool, sess=sess, local_store=lstore,
        global_store=qout.global_store,
        overflow=state.overflow + counters["dropped"] + qout.dropped
        + d4 + d5 + d6,
    )
    metrics = Metrics(
        events_by_kind=counters["events"],
        n_waves=counters["waves"],
        outbox_sent=n_sent,
        qsm_requests=qout.n_requests,
        epoch_end=epoch_end,
        pool_high=counters["pool_high"],
        stale_reads=counters["stale"] + qout.stale,
    )
    return new_state, metrics


def run_epochs_scan(state: ShardState, tables: StaticTables,
                    cfg: EngineConfig, lookahead, n_epochs: int):
    """lax.scan over `n_epochs` epochs; returns stacked per-epoch Metrics."""

    def step(st, _):
        return run_epoch(st, tables, cfg, lookahead)

    return lax.scan(step, state, xs=None, length=n_epochs)
