"""Core datatypes for the PDES engine.

All device state is struct-of-arrays NamedTuples (automatic pytrees) with
int32 fields; timestamps are int32 nanoseconds (exact ordering, TPU-friendly,
no global x64 flag).  Horizon guard: events may not be scheduled beyond
2**30 ns of sim time (~1.07 s) — QKD workloads run in the µs–ms regime.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Event kinds
# ---------------------------------------------------------------------------
KIND_NULL = -1
KIND_EMIT = 0       # sender: prepare photon, schedule ARRIVE + next EMIT
KIND_ARRIVE = 1     # receiver: loss + measurement (or global-QSM request)
KIND_CLASSICAL = 2  # sender: basis reconciliation -> sifted key bit
N_KINDS = 3

TIME_MAX = np.int32(2**30)  # "infinity" / horizon guard

# QSM request ops
QSM_NOP = 0
QSM_WRITE = 1    # store (bit, tx_basis) for (session, photon)
QSM_MEASURE = 2  # measure (session, photon) in rx_basis -> classical reply


class EventPool(NamedTuple):
    """Fixed-capacity struct-of-arrays event pool (one per shard)."""

    time: jnp.ndarray   # int32[cap] ns
    kind: jnp.ndarray   # int32[cap]
    dst: jnp.ndarray    # int32[cap] global router id that executes the event
    a0: jnp.ndarray     # int32[cap] session id
    a1: jnp.ndarray     # int32[cap] photon index
    a2: jnp.ndarray     # int32[cap] packed payload (CLASSICAL: bit0 outcome,
                        #   bit1 rx_basis, bit2 detected)
    valid: jnp.ndarray  # bool[cap]

    @property
    def capacity(self) -> int:
        return self.time.shape[-1]


class Staged(NamedTuple):
    """Events produced by handlers during a wave, before pool insertion."""

    time: jnp.ndarray
    kind: jnp.ndarray
    dst: jnp.ndarray
    a0: jnp.ndarray
    a1: jnp.ndarray
    a2: jnp.ndarray
    valid: jnp.ndarray


class QsmRequests(NamedTuple):
    """Per-epoch staging buffer of global-QSM requests (one per shard)."""

    op: jnp.ndarray        # int32[qcap] QSM_{NOP,WRITE,MEASURE}
    session: jnp.ndarray   # int32[qcap]
    photon: jnp.ndarray    # int32[qcap]
    payload: jnp.ndarray   # int32[qcap] WRITE: bit0 bit, bit1 tx_basis
                           #             MEASURE: bit0 rx_basis
    reply_time: jnp.ndarray  # int32[qcap] timestamp for the reply event
    count: jnp.ndarray     # int32[] number of live requests
    overflow: jnp.ndarray  # int32[] dropped requests (bug indicator)


class SessionState(NamedTuple):
    """Per-QKD-session dynamic state.

    Arrays are GLOBALLY indexed [n_sessions] and replicated in shape; each
    shard only writes rows it owns (rows for foreign sessions hold zeros).
    This makes work-stealing migration a psum + remask (see workstealing.py)
    at the cost of O(total sessions) replication — acceptable for 1e3–1e5
    sessions; shard it for larger (documented in DESIGN.md §5).
    """

    emitted: jnp.ndarray   # int32[S_n] photons emitted so far
    detected: jnp.ndarray  # int32[S_n] photons detected at receiver
    sifted: jnp.ndarray    # int32[S_n] sifted key bits (bases matched)
    errors: jnp.ndarray    # int32[S_n] sifted bits that disagree (QBER num.)
    key_hash: jnp.ndarray  # uint32[S_n] XOR-accumulated fingerprint of the
                           #   sifted key (order-independent -> deterministic
                           #   under wave batching); equivalence-test anchor
    done: jnp.ndarray      # bool[S_n] all photons emitted


class QsmStore(NamedTuple):
    """Quantum state manager store: (bit, tx_basis) per in-flight photon.

    Rows [n_sessions, window] — a circular window over photon indices.
    LOCAL sessions (both endpoints on one shard) are written in-wave.
    GLOBAL sessions go through the request phase:
      * gathered mode: every shard applies every write (replicated mirror of
        the single-server store; cost model bills the server shard),
      * hashed mode: row s is owned by shard hash(s) % n_shards.
    """

    bit: jnp.ndarray       # int32[S_n, W]
    basis: jnp.ndarray     # int32[S_n, W]
    stamp: jnp.ndarray     # int32[S_n, W] photon idx stored (slot-reuse guard)

    @property
    def window(self) -> int:
        return self.bit.shape[-1]


class Metrics(NamedTuple):
    """Per-epoch instrumentation (per shard) — feeds Figs 3–7."""

    events_by_kind: jnp.ndarray  # int32[N_KINDS]
    n_waves: jnp.ndarray         # int32[]
    outbox_sent: jnp.ndarray     # int32[]
    qsm_requests: jnp.ndarray    # int32[]
    epoch_end: jnp.ndarray       # int32[] ns
    pool_high: jnp.ndarray       # int32[] pool occupancy high-water mark
    stale_reads: jnp.ndarray     # int32[] QSM window-reuse misses (must be 0)


class ShardState(NamedTuple):
    """Complete per-shard simulator state (the shard_map/vmap carry)."""

    pool: EventPool
    sess: SessionState
    local_store: QsmStore
    global_store: QsmStore
    router_owner: jnp.ndarray   # int32[n_routers] router -> shard
    session_owner: jnp.ndarray  # int32[n_sessions] sender-side owner shard
    overflow: jnp.ndarray       # int32[] pool insert overflow count
