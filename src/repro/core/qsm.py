"""Global quantum state manager — the two designs under study.

``gathered`` (paper-faithful): one logical QSM server owns every cross-shard
state.  Requests are batched per epoch and all-gathered; every shard applies
the full write set to a replicated mirror and computes every measurement
(SPMD), but the *cost model* bills the whole batch to the single server —
reproducing the fan-in bottleneck of SeQUeNCe's TCP/socket server (the
Python server in the paper's runs).

``hashed`` (beyond-paper, the paper §IV proposal "eliminate the separate
global QSM"): state ownership is hash-partitioned across shards; requests
and replies are routed with all_to_all.  Server work and traffic scale as
1/n_shards instead of accumulating on one host.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
from jax import lax

from repro.core import rng
from repro.core.buffering import route_records
from repro.core.qkd import PHOTON_BITS, StaticTables, pack_classical, \
    store_read, store_write
from repro.core.types import (
    KIND_CLASSICAL, QSM_MEASURE, QSM_WRITE, QsmStore, Staged,
)

GATHERED = "gathered"
HASHED = "hashed"


class QsmPhaseOut(NamedTuple):
    global_store: QsmStore
    replies: Staged          # locally-addressed reply events (insert into pool)
    n_requests: jnp.ndarray  # requests issued by this shard this epoch
    server_load: jnp.ndarray  # requests the billed server processes
    stale: jnp.ndarray
    dropped: jnp.ndarray


def _measure(store: QsmStore, session, photon, rx_basis):
    bit, basis, fresh = store_read(store, session, photon)
    uid = (session << PHOTON_BITS) | photon
    flip = rng.rand_bit(uid, rng.SALT_FLIP)
    outcome = jnp.where(rx_basis == basis, bit, flip)
    return outcome, fresh


def _reply_staged(mask, session, photon, outcome, rx_basis, reply_time,
                  tables: StaticTables):
    n = mask.shape[0]
    s = jnp.clip(session, 0, tables.n_sessions - 1)
    return Staged(
        time=reply_time,
        kind=jnp.full((n,), KIND_CLASSICAL, jnp.int32),
        dst=tables.src[s],
        a0=s,
        a1=photon,
        a2=pack_classical(outcome, rx_basis, jnp.ones((n,), jnp.int32)),
        valid=mask,
    )


def qsm_phase(
    op, session, photon, payload, reply_time, count,
    global_store: QsmStore,
    tables: StaticTables,
    router_owner: jnp.ndarray,
    mode: str,
    n_shards: int,
    axis_name: str,
    route_cap: int,
):
    """Process this epoch's batched QSM requests. Inputs are [qcap] arrays."""
    me = lax.axis_index(axis_name)
    n_requests = count

    if mode == GATHERED:
        gat = lambda x: lax.all_gather(x, axis_name).reshape(
            (n_shards * x.shape[0],) + x.shape[1:])
        op_g, s_g, p_g, pay_g, rt_g = map(
            gat, (op, session, photon, payload, reply_time))

        wmask = op_g == QSM_WRITE
        global_store = store_write(
            global_store, s_g, p_g, pay_g & 1, (pay_g >> 1) & 1, wmask)

        mmask = op_g == QSM_MEASURE
        rx = pay_g & 1
        outcome, fresh = _measure(global_store, s_g, p_g, rx)
        stale = jnp.sum(jnp.where(mmask & ~fresh, 1, 0))

        dest = router_owner[jnp.clip(tables.src[jnp.clip(
            s_g, 0, tables.n_sessions - 1)], 0, tables.n_routers - 1)]
        mine = mmask & (dest == me)
        replies = _reply_staged(mine, s_g, p_g, outcome, rx, rt_g, tables)
        server_load = lax.psum(count, axis_name)  # whole batch on one server
        return QsmPhaseOut(global_store, replies, n_requests, server_load,
                           stale, jnp.int32(0))

    # ---------------- hashed mode ----------------
    owner = session % n_shards
    valid = op != 0
    fields = dict(op=op, session=session, photon=photon, payload=payload,
                  reply_time=reply_time)
    recv, rv, _, drop1 = route_records(fields, owner, valid, n_shards,
                                       route_cap, axis_name)

    r_op = jnp.where(rv, recv["op"], 0)
    wmask = r_op == QSM_WRITE
    global_store = store_write(global_store, recv["session"], recv["photon"],
                               recv["payload"] & 1,
                               (recv["payload"] >> 1) & 1, wmask)
    mmask = r_op == QSM_MEASURE
    rx = recv["payload"] & 1
    outcome, fresh = _measure(global_store, recv["session"], recv["photon"],
                              rx)
    stale = jnp.sum(jnp.where(mmask & ~fresh, 1, 0))

    # route replies to the shard owning the sender router
    s_c = jnp.clip(recv["session"], 0, tables.n_sessions - 1)
    rdest = router_owner[jnp.clip(tables.src[s_c], 0, tables.n_routers - 1)]
    reply_fields = dict(
        session=recv["session"], photon=recv["photon"],
        outcome=outcome, rx=rx, reply_time=recv["reply_time"])
    rrecv, rrv, _, drop2 = route_records(reply_fields, rdest, mmask,
                                         n_shards, route_cap, axis_name)
    replies = _reply_staged(rrv, rrecv["session"], rrecv["photon"],
                            rrecv["outcome"], rrecv["rx"],
                            rrecv["reply_time"], tables)
    server_load = jnp.sum(mmask.astype(jnp.int32) +
                          wmask.astype(jnp.int32))  # my partition only
    return QsmPhaseOut(global_store, replies, n_requests, server_load,
                       stale, drop1 + drop2)
