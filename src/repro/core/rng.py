"""Counter-based deterministic RNG for event-level randomness.

Every random decision in the simulator (photon bit, preparation basis,
measurement basis, loss, tie-breaks) is a pure function of a globally unique
event identifier ``uid`` plus a per-purpose ``salt``.  This makes simulation
results bit-identical for ANY shard count and ANY partitioning — the
serial-equivalence guarantee a conservative PDES promises (and the property
our tests pin down).

We use a splitmix32-style integer mixer rather than threefry keys so the same
code runs unchanged inside Pallas kernel bodies (pure uint32 arithmetic, no
PRNG key plumbing) and is cheap on the VPU.
"""
from __future__ import annotations

import jax.numpy as jnp

# Distinct salts per random purpose (arbitrary odd constants).  Kept as
# Python ints so Pallas kernel bodies see literals, not captured tracers.
SALT_BIT = 0x9E3779B1
SALT_TX_BASIS = 0x85EBCA77
SALT_RX_BASIS = 0xC2B2AE3D
SALT_LOSS = 0x27D4EB2F
SALT_FLIP = 0x165667B1


def mix32(x: jnp.ndarray, salt) -> jnp.ndarray:
    """splitmix32 finalizer over (x + salt); returns uniform uint32."""
    z = x.astype(jnp.uint32) + jnp.uint32(salt)
    z = (z ^ (z >> 16)) * jnp.uint32(0x85EBCA6B)
    z = (z ^ (z >> 13)) * jnp.uint32(0xC2B2AE35)
    z = z ^ (z >> 16)
    # one extra round for avalanche on small sequential inputs
    z = (z + jnp.uint32(0x9E3779B9))
    z = (z ^ (z >> 15)) * jnp.uint32(0x2C1B3C6D)
    z = (z ^ (z >> 12)) * jnp.uint32(0x297A2D39)
    z = z ^ (z >> 15)
    return z


def uniform01(x: jnp.ndarray, salt) -> jnp.ndarray:
    """Uniform float32 in [0, 1) derived from mix32."""
    u = mix32(x, salt)
    return (u >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def rand_bit(x: jnp.ndarray, salt) -> jnp.ndarray:
    """Uniform bit in {0, 1} (int32)."""
    return (mix32(x, salt) & jnp.uint32(1)).astype(jnp.int32)


def bernoulli(x: jnp.ndarray, salt, p) -> jnp.ndarray:
    """Bernoulli(p) as bool, deterministic in (x, salt)."""
    return uniform01(x, salt) < p
