"""Decoder-only LM assembly: init / forward / prefill / decode.

Depth is organised as `pattern_repeats` copies of `cfg.block_pattern`
(the "group"); parameters for all groups are stacked on a leading axis and
the forward pass lax.scans over them — HLO size stays O(pattern), which
keeps 512-device dry-run compiles tractable for 80-layer models.

zamba2's SHARED_ATTN block applies one un-stacked parameter set inside
every group — Zamba2's weight-shared global block, expressed as a scan
closure constant.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import base as B
from repro.models import attention as A
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.layers import (
    embed, embed_init, mlp, mlp_init, rmsnorm, rmsnorm_init, unembed,
)
from repro.parallel.sharding import hint


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _block_init(kind, key, cfg, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    if kind in (B.ATTN, B.ATTN_LOCAL):
        return {"ln1": rmsnorm_init(d, dtype),
                "attn": A.attn_init(ks[0], cfg, dtype),
                "ln2": rmsnorm_init(d, dtype),
                "mlp": mlp_init(ks[1], d, cfg.d_ff, dtype)}
    if kind == B.MOE:
        return {"ln1": rmsnorm_init(d, dtype),
                "attn": A.attn_init(ks[0], cfg, dtype),
                "ln2": rmsnorm_init(d, dtype),
                "moe": M.moe_init(ks[1], cfg, dtype)}
    if kind == B.MAMBA2:
        return {"ln1": rmsnorm_init(d, dtype),
                "mixer": S.mamba2_init(ks[0], cfg, dtype)}
    if kind == B.MLSTM:
        return {"ln1": rmsnorm_init(d, dtype),
                "mixer": S.mlstm_init(ks[0], cfg, dtype)}
    if kind == B.SLSTM:
        return {"ln1": rmsnorm_init(d, dtype),
                "mixer": S.slstm_init(ks[0], cfg, dtype)}
    if kind == B.SHARED_ATTN:
        return {}  # weights live in params["shared"]
    raise ValueError(kind)


def init_lm(cfg: B.ArchConfig, key, dtype=jnp.float32):
    keys = jax.random.split(key, cfg.pattern_repeats + 3)
    params = {
        "embed": embed_init(keys[-1], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = embed_init(keys[-2], cfg.vocab_size, cfg.d_model,
                                    dtype)
    if B.SHARED_ATTN in cfg.block_pattern:
        params["shared"] = _block_init(B.ATTN, keys[-3], cfg, dtype)

    def group_init(gkey):
        bks = jax.random.split(gkey, len(cfg.block_pattern))
        return {f"b{i}": _block_init(kind, bks[i], cfg, dtype)
                for i, kind in enumerate(cfg.block_pattern)}

    groups = [group_init(keys[g]) for g in range(cfg.pattern_repeats)]
    params["groups"] = jax.tree.map(lambda *xs: jnp.stack(xs), *groups)
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------
def _apply_block(kind, bp, x, cfg, shared, aux):
    if kind == B.SHARED_ATTN:
        bp, kind = shared, B.ATTN
    if kind in (B.ATTN, B.ATTN_LOCAL):
        window = cfg.window if kind == B.ATTN_LOCAL else None
        h, _ = A.attention_prefill(
            bp["attn"], rmsnorm(bp["ln1"], x, cfg.norm_eps), cfg,
            window=window)
        x = x + h
        x = x + mlp(bp["mlp"], rmsnorm(bp["ln2"], x, cfg.norm_eps),
                    cfg.mlp_kind)
    elif kind == B.MOE:
        h, _ = A.attention_prefill(
            bp["attn"], rmsnorm(bp["ln1"], x, cfg.norm_eps), cfg)
        x = x + h
        y, moe_aux = M.moe_apply(bp["moe"],
                                 rmsnorm(bp["ln2"], x, cfg.norm_eps), cfg)
        x = x + y
        aux["moe_aux_loss"] += moe_aux["aux_loss"]
        aux["moe_dropped"] += moe_aux["dropped"]
    elif kind == B.MAMBA2:
        x = x + S.mamba2_apply(bp["mixer"],
                               rmsnorm(bp["ln1"], x, cfg.norm_eps), cfg)
    elif kind == B.MLSTM:
        x = x + S.mlstm_apply(bp["mixer"],
                              rmsnorm(bp["ln1"], x, cfg.norm_eps), cfg)
    elif kind == B.SLSTM:
        x = x + S.slstm_apply(bp["mixer"],
                              rmsnorm(bp["ln1"], x, cfg.norm_eps), cfg)
    else:
        raise ValueError(kind)
    seq = "model" if cfg.sp_residual else None
    return hint(x, "dp", seq, None), aux


def forward(params, cfg: B.ArchConfig, tokens, *,
            prefix_embeds: Optional[jnp.ndarray] = None):
    """tokens (B,T) [+ prefix_embeds (B,P,d) for VLM] -> logits, aux."""
    x = embed(params["embed"], tokens) * cfg.d_model ** 0.5
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x = hint(x, "dp", None, None)
    shared = params.get("shared")

    aux0 = {"moe_aux_loss": jnp.float32(0.0), "moe_dropped": jnp.int32(0)}

    # remat each group (backward recomputes the group forward — saves only
    # the scan carry) + Megatron-style sequence parallelism on the carry
    # (saved activations shard seq over 'model'), which is what bounds
    # activation memory for the deep configs.
    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def group_fn(carry, gparams):
        x, aux = carry
        for i, kind in enumerate(cfg.block_pattern):
            x, aux = _apply_block(kind, gparams[f"b{i}"], x, cfg, shared,
                                  aux)
        return (hint(x, "dp", "model", None), aux), None

    carry0 = (hint(x, "dp", "model", None), aux0)
    if cfg.unroll_groups:
        carry = carry0
        for g in range(cfg.pattern_repeats):
            gp = jax.tree.map(lambda a, g=g: a[g], params["groups"])
            carry, _ = group_fn(carry, gp)
        x, aux = carry
    else:
        (x, aux), _ = jax.lax.scan(group_fn, carry0, params["groups"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg.tie_embeddings,
                     params.get("head"))
    return hint(logits, "dp", None, "model"), aux


# ---------------------------------------------------------------------------
# decode (one token against caches)
# ---------------------------------------------------------------------------
def _block_cache(kind, cfg, batch, seq_len, dtype):
    if kind in (B.ATTN, B.ATTN_LOCAL, B.SHARED_ATTN, B.MOE):
        return A.init_cache(cfg, batch, seq_len, dtype)
    if kind == B.MAMBA2:
        return S.mamba2_init_cache(cfg, batch, dtype)
    if kind == B.MLSTM:
        return S.mlstm_init_cache(cfg, batch, dtype)
    if kind == B.SLSTM:
        return S.slstm_init_cache(cfg, batch, dtype)
    raise ValueError(kind)


def init_caches(cfg: B.ArchConfig, batch: int, seq_len: int,
                dtype=jnp.float32):
    """Stacked per-group caches (leading axis = pattern_repeats)."""
    def one_group():
        return {f"b{i}": _block_cache(kind, cfg, batch, seq_len, dtype)
                for i, kind in enumerate(cfg.block_pattern)}

    groups = [one_group() for _ in range(cfg.pattern_repeats)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *groups)


def _apply_block_decode(kind, bp, x, cfg, shared, cache):
    if kind == B.SHARED_ATTN:
        bp, kind = shared, B.ATTN
    if kind in (B.ATTN, B.ATTN_LOCAL, B.MOE):
        window = cfg.window if kind == B.ATTN_LOCAL else None
        h, cache = A.attention_decode(
            bp["attn"], rmsnorm(bp["ln1"], x, cfg.norm_eps), cfg,
            cache, window=window)
        x = x + h
        if kind == B.MOE:
            y, _ = M.moe_apply(bp["moe"],
                               rmsnorm(bp["ln2"], x, cfg.norm_eps), cfg)
            x = x + y
        else:
            x = x + mlp(bp["mlp"], rmsnorm(bp["ln2"], x, cfg.norm_eps),
                        cfg.mlp_kind)
    elif kind == B.MAMBA2:
        h, cache = S.mamba2_decode(bp["mixer"],
                                   rmsnorm(bp["ln1"], x, cfg.norm_eps),
                                   cfg, cache)
        x = x + h
    elif kind == B.MLSTM:
        h, cache = S.mlstm_decode(bp["mixer"],
                                  rmsnorm(bp["ln1"], x, cfg.norm_eps),
                                  cfg, cache)
        x = x + h
    elif kind == B.SLSTM:
        h, cache = S.slstm_decode(bp["mixer"],
                                  rmsnorm(bp["ln1"], x, cfg.norm_eps),
                                  cfg, cache)
        x = x + h
    else:
        raise ValueError(kind)
    return x, cache


def decode_step(params, cfg: B.ArchConfig, token, caches):
    """token (B,1) + stacked caches -> (logits (B,1,V), new caches)."""
    x = embed(params["embed"], token) * cfg.d_model ** 0.5
    shared = params.get("shared")

    def group_fn(x, inp):
        gparams, gcache = inp
        new_cache = {}
        for i, kind in enumerate(cfg.block_pattern):
            x, new_cache[f"b{i}"] = _apply_block_decode(
                kind, gparams[f"b{i}"], x, cfg, shared, gcache[f"b{i}"])
        return x, new_cache

    if cfg.unroll_groups:
        ncs = []
        for g in range(cfg.pattern_repeats):
            sel = lambda a, g=g: a[g]
            x, nc = group_fn(x, (jax.tree.map(sel, params["groups"]),
                                 jax.tree.map(sel, caches)))
            ncs.append(nc)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
    else:
        x, new_caches = jax.lax.scan(group_fn, x,
                                     (params["groups"], caches))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg.tie_embeddings,
                     params.get("head"))
    return logits, new_caches
