"""Mixture-of-experts FFN with sort-based dispatch and static capacity.

Expert-parallel friendly: expert weight tensors carry a leading E axis
(sharded over the `model` mesh axis); dispatch is the sort/rank/scatter
pattern (no (T, E, C) one-hot blowup):

  route -> top-k -> stable-sort assignments by expert -> rank within expert
  -> scatter into (E, C, d) buffers -> batched expert einsum -> weighted
  scatter-add back to tokens.

Two dispatch modes (§Perf iteration, EXPERIMENTS.md):
  * global (baseline, ``cfg.moe_dp_slices == 0``): one argsort over every
    assignment in the global batch.  Semantically clean but GSPMD must
    all-gather the token stream to sort it — the collective pathology the
    baseline roofline records.
  * sliced (``moe_dp_slices = DP degree``): tokens reshape to
    (slices, N/slices) with the slice dim sharded over 'data'; each slice
    sorts/scatters locally with per-slice capacity C/slices (what real MoE
    systems do — per-device capacity), and only the (slices, E, C', d)
    expert buffers cross the network to the expert owners.

Overflowing assignments beyond capacity are dropped (token keeps its other
experts / residual path); per-expert load is returned for the telemetry
that mirrors the paper's straggler analysis (DESIGN.md §4: expert skew IS
the partitioning/straggler problem at token granularity).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import truncated_normal
from repro.parallel.sharding import hint


def moe_init(key, cfg, dtype):
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in, s_out = d ** -0.5, f ** -0.5
    return {
        "router": truncated_normal(k1, (d, E), jnp.float32, s_in),
        "wi": truncated_normal(k2, (E, d, f), dtype, s_in),
        "wg": truncated_normal(k3, (E, d, f), dtype, s_in),
        "wo": truncated_normal(k4, (E, f, d), dtype, s_out),
    }


def _dispatch_ffn(p, xf, cfg, C):
    """Core dispatch + expert FFN for a flat token slice xf (N, d)."""
    N, d = xf.shape
    E, k = cfg.n_experts, cfg.moe_top_k

    logits = xf.astype(jnp.float32) @ p["router"]          # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                   # (N, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    fe = eidx.reshape(-1)                                  # (N*k,)
    fw = gate.reshape(-1).astype(xf.dtype)
    ft = jnp.repeat(jnp.arange(N, dtype=jnp.int32), k)
    order = jnp.argsort(fe, stable=True)
    se, sw, stok = fe[order], fw[order], ft[order]
    pos = jnp.arange(N * k, dtype=jnp.int32)
    rank = pos - jnp.searchsorted(se, se, side="left").astype(jnp.int32)
    ok = rank < C
    slot = jnp.where(ok, se * C + rank, E * C)

    buf = jnp.zeros((E * C, d), xf.dtype).at[slot].set(
        xf[stok] * ok[:, None].astype(xf.dtype), mode="drop")
    buf = buf.reshape(E, C, d)

    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, p["wo"])
    out = out.reshape(E * C, d)

    contrib = out[jnp.clip(slot, 0, E * C - 1)] * \
        (sw * ok.astype(sw.dtype))[:, None]
    y = jnp.zeros((N, d), xf.dtype).at[stok].add(contrib)

    frac_tokens = jnp.mean(
        jax.nn.one_hot(eidx[:, 0], E, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux_loss = E * jnp.sum(frac_tokens * frac_probs)
    load = jnp.zeros((E,), jnp.int32).at[se].add(
        ok.astype(jnp.int32), mode="drop")
    dropped = jnp.sum((~ok).astype(jnp.int32))
    return y, dict(aux_loss=aux_loss, expert_load=load, dropped=dropped)


def _dispatch_ffn_sliced(p, xs, cfg, C):
    """Batched-over-slices dispatch: xs (S, n, d), slice dim sharded over
    'data'.  Sort/scatter/gather are slice-local; expert buffers are
    explicitly resharded to E-over-'model' so the expert FFN contracts d
    locally (one data->model reshard each way instead of all-reducing
    activation partial sums)."""
    S, n, d = xs.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    sidx = jnp.arange(S, dtype=jnp.int32)[:, None]

    logits = xs.astype(jnp.float32) @ p["router"]            # (S, n, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                     # (S, n, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    fe = eidx.reshape(S, n * k)
    fw = gate.reshape(S, n * k).astype(xs.dtype)
    ft = jnp.broadcast_to(
        jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)[None], (S, n * k))
    order = jnp.argsort(fe, axis=-1, stable=True)
    se = jnp.take_along_axis(fe, order, axis=-1)
    sw = jnp.take_along_axis(fw, order, axis=-1)
    stok = jnp.take_along_axis(ft, order, axis=-1)
    pos = jnp.arange(n * k, dtype=jnp.int32)[None]
    first = jax.vmap(lambda a: jnp.searchsorted(a, a, side="left"))(se)
    rank = pos - first.astype(jnp.int32)
    ok = rank < C
    slot = jnp.where(ok, se * C + rank, E * C)

    gathered = jnp.take_along_axis(xs, stok[..., None], axis=1)
    gathered = hint(gathered * ok[..., None].astype(xs.dtype),
                    "data", None, None)
    buf = jnp.zeros((S, E * C, d), xs.dtype).at[sidx, slot].set(
        gathered, mode="drop")
    # reshard: slice-local buffers -> expert owners (E over 'model')
    buf = hint(buf.reshape(S, E, C, d), "data", "model", None, None)

    h = jnp.einsum("secd,edf->secf", buf, p["wi"])
    g = jnp.einsum("secd,edf->secf", buf, p["wg"])
    out = jnp.einsum("secf,efd->secd", jax.nn.silu(g) * h, p["wo"])
    out = hint(out, "data", "model", None, None)
    out = out.reshape(S, E * C, d)

    contrib = jnp.take_along_axis(
        out, jnp.clip(slot, 0, E * C - 1)[..., None], axis=1)
    contrib = hint(contrib, "data", None, None) * \
        (sw * ok.astype(sw.dtype))[..., None]
    y = jnp.zeros((S, n, d), xs.dtype).at[sidx, stok].add(contrib)
    y = hint(y, "data", None, None)

    frac_tokens = jnp.mean(
        jax.nn.one_hot(eidx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux_loss = E * jnp.sum(frac_tokens * frac_probs)
    load = jnp.zeros((E,), jnp.int32).at[se.reshape(-1)].add(
        ok.reshape(-1).astype(jnp.int32), mode="drop")
    dropped = jnp.sum((~ok).astype(jnp.int32))
    return y, dict(aux_loss=aux_loss, expert_load=load, dropped=dropped)


def _moe_shardmap(p, x, cfg, mesh):
    """Explicit expert parallelism (§Perf v3).

    shard_map over the full mesh: tokens enter sharded over DP and
    REPLICATED across 'model'; every model shard computes the (identical)
    routing, keeps only assignments owned by its E/TP experts, runs their
    FFN entirely locally (full d after the explicit FSDP weight gather),
    and one psum over 'model' combines expert contributions.  Per-layer
    comm = activations psum + FSDP weight gather — no data-dependent
    GSPMD resharding of the dispatch stream.
    """
    from jax import lax
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import dp_axes

    B, T, d = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    dp = dp_axes(mesh) or ("data",)
    dp = tuple(a for a in dp if a in mesh.axis_names)
    import numpy as _np
    S_dp = int(_np.prod([mesh.shape[a] for a in dp]))
    TP = mesh.shape.get("model", 1)
    if E % TP or (B * T) % S_dp:
        return None  # caller falls back
    E_l = E // TP
    n_l = (B * T) // S_dp
    # capacity per expert PER DATA ROW (each row dispatches only its own
    # n_l tokens) — sizing from the global batch would pad every expert
    # buffer by the DP degree and burn that factor in empty-slot FFN work
    C_e = int(-(-k * n_l // E) * cfg.capacity_factor)
    C_e = max(8, -(-C_e // 8) * 8)

    dp_entry = dp if len(dp) > 1 else dp[0]

    def body(xb, router, wi, wg, wo):
        xl = xb.reshape(-1, d)                              # (n_l, d)
        # FSDP weights: gather the dp-sharded dim explicitly
        if wi.shape[1] != d:
            wi = lax.all_gather(wi, dp_entry, axis=1, tiled=True)
            wg = lax.all_gather(wg, dp_entry, axis=1, tiled=True)
        if wo.shape[2] != d:
            wo = lax.all_gather(wo, dp_entry, axis=2, tiled=True)
        col = lax.axis_index("model")

        logits = xl.astype(jnp.float32) @ router            # (n_l, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, eidx = jax.lax.top_k(probs, k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

        fe = eidx.reshape(-1)
        fw = gate.reshape(-1).astype(xl.dtype)
        ft = jnp.repeat(jnp.arange(n_l, dtype=jnp.int32), k)
        mine = (fe // E_l) == col
        e_loc = fe - col * E_l
        key = jnp.where(mine, e_loc, E_l)
        order = jnp.argsort(key, stable=True)
        sk = key[order]
        sw = fw[order]
        stok = ft[order]
        pos = jnp.arange(n_l * k, dtype=jnp.int32)
        first = jnp.searchsorted(sk, sk, side="left").astype(jnp.int32)
        rank = pos - first
        ok = (sk < E_l) & (rank < C_e)
        slot = jnp.where(ok, sk * C_e + rank, E_l * C_e)

        buf = jnp.zeros((E_l * C_e, d), xl.dtype).at[slot].set(
            xl[stok] * ok[:, None].astype(xl.dtype), mode="drop")
        buf = buf.reshape(E_l, C_e, d)
        h = jnp.einsum("ecd,edf->ecf", buf, wi)
        g = jnp.einsum("ecd,edf->ecf", buf, wg)
        out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, wo)
        out = out.reshape(E_l * C_e, d)

        contrib = out[jnp.clip(slot, 0, E_l * C_e - 1)] * \
            (sw * ok.astype(sw.dtype))[:, None]
        y_part = jnp.zeros((n_l, d), xl.dtype).at[stok].add(contrib)
        y = lax.psum(y_part, "model")                       # EP combine

        # aux: identical routing on every col; loads are col-local
        frac_tokens = jnp.mean(
            jax.nn.one_hot(eidx[:, 0], E, dtype=jnp.float32), axis=0)
        frac_probs = jnp.mean(probs, axis=0)
        aux_loss = lax.pmean(E * jnp.sum(frac_tokens * frac_probs),
                             dp_entry)
        load_l = jnp.zeros((E_l,), jnp.int32).at[
            jnp.where(ok, sk, E_l)].add(1, mode="drop")
        load = lax.psum(lax.all_gather(load_l, "model", tiled=True),
                        dp_entry)
        dropped = lax.psum(lax.psum(
            jnp.sum((mine & ~ok).astype(jnp.int32)), "model"), dp_entry)
        return y.reshape(xb.shape), aux_loss, load, dropped

    y, aux_loss, load, dropped = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_entry, None, None), P(None, None),
                  P("model", dp_entry, None), P("model", dp_entry, None),
                  P("model", None, dp_entry)),
        out_specs=(P(dp_entry, None, None), P(), P(), P()),
        check_vma=False,
    )(x, p["router"], p["wi"], p["wg"], p["wo"])
    return y, dict(aux_loss=aux_loss, expert_load=load, dropped=dropped)


def moe_apply(p, x, cfg):
    """x (B,T,d) -> (y (B,T,d), aux dict)."""
    B, T, d = x.shape
    N = B * T
    E, k = cfg.n_experts, cfg.moe_top_k
    C = int(-(-k * N // E) * cfg.capacity_factor)
    C = max(8, -(-C // 8) * 8)

    if cfg.moe_shard_map:
        from repro.parallel.sharding import active_mesh
        mesh = active_mesh()
        if mesh is not None:
            out = _moe_shardmap(p, x, cfg, mesh)
            if out is not None:
                return out

    xf = x.reshape(N, d)
    S = cfg.moe_dp_slices
    if S > 1 and N % S == 0:
        C_l = max(8, -(-C // S // 8) * 8)
        xs = hint(xf.reshape(S, N // S, d), "data", None, None)
        y, aux = _dispatch_ffn_sliced(p, xs, cfg, C_l)
        y = y.reshape(N, d)
    else:
        y, aux = _dispatch_ffn(p, xf, cfg, C)
    return y.reshape(B, T, d), aux
