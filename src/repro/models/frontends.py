"""Modality frontends — STUBS per the brief.

``[audio]`` and ``[vlm]`` cells specify the transformer BACKBONE only; the
conv/mel frontend (whisper) and the vision tower (pixtral) are replaced by
precomputed embeddings that `input_specs()` supplies directly.  These
helpers generate deterministic synthetic embeddings for smoke tests and
examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def audio_frames(key, batch: int, frames: int, d_model: int,
                 dtype=jnp.float32):
    """Stand-in for whisper's conv-downsampled mel frames."""
    return jax.random.normal(key, (batch, frames, d_model), dtype) * 0.02


def vision_patches(key, batch: int, patches: int, d_model: int,
                   dtype=jnp.float32):
    """Stand-in for pixtral's ViT patch embeddings."""
    return jax.random.normal(key, (batch, patches, d_model), dtype) * 0.02
