"""Flash-equivalent attention in pure XLA ops (online softmax over KV
chunks).

This is the attention the dry-run lowers on non-TPU backends: same O(T*d)
working set as the Pallas kernel (never materializes the (T, S) score
matrix), so the roofline terms extracted from the compiled HLO reflect the
TPU execution structure rather than a dense oracle.  With
``unroll=True`` (the dry-run's R=1/R=2 depth lowerings) the chunk loop is
emitted as straight-line HLO so XLA cost analysis counts every chunk.

``causal_skip=True`` (beyond-baseline optimization, §Perf) also blocks the
query dimension and skips fully-masked (q-block, kv-chunk) pairs — halving
attention FLOPs for causal masks.  Requires unroll (static skip decisions).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(qpos, kpos, causal, window, kv_len):
    m = kpos < kv_len
    if causal:
        m &= qpos >= kpos
    if window is not None:
        m &= (qpos - kpos) < window
    return m


def chunked_attention(q, k, v, *, causal=True, window=None, sm_scale=None,
                      chunk=1024, q_chunk=None, unroll=False,
                      causal_skip=False):
    """q (B,H,T,D), k/v (B,Hkv,S,D) -> (B,H,T,D)."""
    B, H, T, D = q.shape
    _, Hkv, S, _ = k.shape
    if sm_scale is None:
        sm_scale = D ** -0.5
    g = H // Hkv
    c = min(chunk, S)
    nc = -(-S // c)
    pad_s = nc * c - S
    if pad_s:
        padw = ((0, 0), (0, 0), (0, pad_s), (0, 0))
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)

    qg = q.reshape(B, Hkv, g, T, D).astype(jnp.float32) * sm_scale
    kc = k.reshape(B, Hkv, nc, c, D).astype(jnp.float32)
    vc = v.reshape(B, Hkv, nc, c, D).astype(jnp.float32)

    def make_step(qpos):
        tq = qpos.shape[0]

        def step(carry, inp):
            m_run, l_run, acc = carry
            kj, vj, off = inp
            s = jnp.einsum("bngtd,bncd->bngtc", qg_blk, kj)
            kpos = off + jnp.arange(c)
            msk = _mask(qpos[:, None], kpos[None, :], causal, window, S)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_run * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bngtc,bncd->bngtd", p, vj)
            return (m_new, l_new, acc), None

        return step

    def run_block(qg_blk_in, qpos):
        nonlocal qg_blk
        qg_blk = qg_blk_in
        tq = qpos.shape[0]
        m0 = jnp.full((B, Hkv, g, tq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, g, tq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, g, tq, D), jnp.float32)
        offs = jnp.arange(nc) * c
        step = make_step(qpos)
        if unroll:
            carry = (m0, l0, a0)
            for j in range(nc):
                if causal and causal_skip:
                    q_hi = int(qpos[-1])
                    if j * c > q_hi:
                        continue  # fully-masked chunk: skip statically
                carry, _ = step(carry, (kc[:, :, j], vc[:, :, j],
                                        jnp.int32(j * c)))
            m_run, l_run, acc = carry
        else:
            (m_run, l_run, acc), _ = jax.lax.scan(
                step, (m0, l0, a0),
                (kc.transpose(2, 0, 1, 3, 4), vc.transpose(2, 0, 1, 3, 4),
                 offs))
        l_run = jnp.where(l_run == 0.0, 1.0, l_run)
        return acc / l_run[..., None]

    qg_blk = None
    if causal_skip and unroll and causal:
        bq = q_chunk or c
        nq = -(-T // bq)
        outs = []
        for i in range(nq):
            lo, hi = i * bq, min((i + 1) * bq, T)
            qpos = jnp.arange(lo, hi)
            outs.append(run_block(qg[:, :, :, lo:hi], qpos))
        out = jnp.concatenate(outs, axis=3)
    else:
        out = run_block(qg, jnp.arange(T))
    return out.reshape(B, H, T, D).astype(q.dtype)
