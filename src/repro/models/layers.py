"""Shared layers: RMSNorm, RoPE, MLPs, embeddings (pure functions + inits).

Parameters are plain dicts of jnp arrays; initializers take an explicit key
and dtype.  Logical sharding of activations is applied in transformer.py via
repro.parallel.sharding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def truncated_normal(key, shape, dtype, scale):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rmsnorm_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x, positions, theta):
    """x (..., T, H, D) with D even; positions (..., T)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------
def mlp_init(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    return {
        "wi": truncated_normal(k1, (d_model, d_ff), dtype, s_in),
        "wg": truncated_normal(k2, (d_model, d_ff), dtype, s_in),
        "wo": truncated_normal(k3, (d_ff, d_model), dtype, s_out),
    }


def mlp(p, x, kind: str):
    h = jnp.einsum("btd,df->btf", x, p["wi"])
    g = jnp.einsum("btd,df->btf", x, p["wg"])
    act = jax.nn.gelu(g, approximate=True) if kind == "geglu" \
        else jax.nn.silu(g)
    return jnp.einsum("btf,fd->btd", act * h, p["wo"])


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------
def embed_init(key, vocab, d_model, dtype):
    # N(0, 1/sqrt(d)): with the x*sqrt(d) embedding scaling this gives unit
    # activations and O(1) tied-head logits at init.
    return {"table": truncated_normal(key, (vocab, d_model), dtype,
                                      d_model ** -0.5)}


def embed(p, tokens):
    return p["table"][tokens]


def unembed(p_embed, x, tied: bool, p_head=None):
    table = p_embed["table"] if tied else p_head["table"]
    return jnp.einsum("btd,vd->btv", x, table)
