"""Unified model API over all 10 architectures.

Dispatches decoder-only vs encoder-decoder vs VLM-prefix; provides the three
step bodies (train / prefill / decode) that launch + dry-run lower, the
ShapeDtypeStruct input specs per (arch x shape) cell, and synthetic batches
for smoke tests.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import base as B
from repro.models import encdec as ED
from repro.models import frontends as F
from repro.models import transformer as T


def is_encdec(cfg: B.ArchConfig) -> bool:
    return cfg.encoder_layers > 0


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_params(cfg: B.ArchConfig, key, dtype=jnp.float32):
    if is_encdec(cfg):
        return ED.init_encdec(cfg, key, dtype)
    return T.init_lm(cfg, key, dtype)


# ---------------------------------------------------------------------------
# loss / train forward
# ---------------------------------------------------------------------------
def _xent(logits, targets, mask):
    """TP-friendly cross entropy: every vocab-axis op is a reduction (GSPMD
    keeps the vocab shard and inserts partial-reduce + all-reduce); the
    gold logit uses an iota-select instead of a gather so the sharded axis
    is never re-materialized unsharded."""
    from repro.parallel.sharding import hint

    lg = hint(logits, "dp", None, "model")
    v = lg.shape[-1]
    m = jnp.max(lg, axis=-1, keepdims=True)
    shifted = (lg - m).astype(jnp.float32)
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0].astype(
        jnp.float32)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, (v,), 0)
    onehot = vocab_iota[None, None, :] == targets[..., None]
    gold = jnp.sum(jnp.where(onehot, lg.astype(jnp.float32), 0.0), axis=-1)
    nll = (lse - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def loss_fn(params, cfg: B.ArchConfig, batch):
    """batch: tokens (B,T) [+ frames | patches].  Next-token LM loss."""
    if is_encdec(cfg):
        logits, aux = ED.forward(params, cfg, batch["tokens"],
                                 batch["frames"])
        text_logits = logits
    else:
        logits, aux = T.forward(params, cfg, batch["tokens"],
                                prefix_embeds=batch.get("patches"))
        p = cfg.patch_tokens
        text_logits = logits[:, p:] if p else logits
    targets = batch["tokens"][:, 1:]
    mask = (targets >= 0).astype(jnp.float32)
    loss = _xent(text_logits[:, :-1], jnp.maximum(targets, 0), mask)
    loss = loss + 0.01 * aux["moe_aux_loss"] / max(cfg.n_layers, 1)
    return loss, aux


def prefill_step(params, cfg: B.ArchConfig, batch):
    """Inference prefill: logits for the full prompt."""
    if is_encdec(cfg):
        logits, _ = ED.forward(params, cfg, batch["tokens"],
                               batch["frames"])
    else:
        logits, _ = T.forward(params, cfg, batch["tokens"],
                              prefix_embeds=batch.get("patches"))
    return logits


def decode_step(params, cfg: B.ArchConfig, batch, caches):
    """One new token against a seq_len cache -> (logits (B,1,V), caches)."""
    if is_encdec(cfg):
        return ED.decode_step(params, cfg, batch["token"], caches,
                              batch["enc_states"])
    return T.decode_step(params, cfg, batch["token"], caches)


def make_caches(cfg: B.ArchConfig, batch: int, seq_len: int,
                dtype=jnp.float32):
    if is_encdec(cfg):
        return ED.init_caches(cfg, batch, seq_len, dtype)
    return T.init_caches(cfg, batch, seq_len, dtype)


# ---------------------------------------------------------------------------
# input specs (dry-run) + synthetic batches (smoke)
# ---------------------------------------------------------------------------
def supports_shape(cfg: B.ArchConfig, shape: B.ShapeConfig) -> bool:
    """long_500k only for sub-quadratic archs (DESIGN.md §4)."""
    if shape.name == "long_500k":
        return cfg.subquadratic
    return True


def input_specs(cfg: B.ArchConfig, shape: B.ShapeConfig,
                dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    Bb, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if is_encdec(cfg):
            return {
                "tokens": jax.ShapeDtypeStruct((Bb, S), i32),
                "frames": jax.ShapeDtypeStruct(
                    (Bb, cfg.encoder_frames, cfg.d_model), dtype),
            }
        batch = {"tokens": jax.ShapeDtypeStruct((Bb, S), i32)}
        if cfg.patch_tokens:
            batch["tokens"] = jax.ShapeDtypeStruct(
                (Bb, S - cfg.patch_tokens), i32)
            batch["patches"] = jax.ShapeDtypeStruct(
                (Bb, cfg.patch_tokens, cfg.d_model), dtype)
        return batch
    # decode: one token + cache of length seq_len
    batch = {"token": jax.ShapeDtypeStruct((Bb, 1), i32)}
    if is_encdec(cfg):
        batch["enc_states"] = jax.ShapeDtypeStruct(
            (Bb, cfg.encoder_frames, cfg.d_model), dtype)
    caches = jax.eval_shape(
        lambda: make_caches(cfg, Bb, S, dtype))
    return batch, caches


def synth_batch(cfg: B.ArchConfig, shape: B.ShapeConfig, key,
                dtype=jnp.float32):
    """Concrete random batch (smoke tests / examples)."""
    Bb, S = shape.global_batch, shape.seq_len
    k1, k2 = jax.random.split(key)
    if shape.kind in ("train", "prefill"):
        if is_encdec(cfg):
            return {
                "tokens": jax.random.randint(k1, (Bb, S), 0,
                                             cfg.vocab_size, jnp.int32),
                "frames": F.audio_frames(k2, Bb, cfg.encoder_frames,
                                         cfg.d_model, dtype),
            }
        batch = {"tokens": jax.random.randint(
            k1, (Bb, S - cfg.patch_tokens if cfg.patch_tokens else S),
            0, cfg.vocab_size, jnp.int32)}
        if cfg.patch_tokens:
            batch["patches"] = F.vision_patches(k2, Bb, cfg.patch_tokens,
                                                cfg.d_model, dtype)
        return batch
    batch = {"token": jax.random.randint(k1, (Bb, 1), 0, cfg.vocab_size,
                                         jnp.int32)}
    if is_encdec(cfg):
        batch["enc_states"] = F.audio_frames(k2, Bb, cfg.encoder_frames,
                                             cfg.d_model, dtype)
    caches = make_caches(cfg, Bb, S, dtype)
    return batch, caches
