"""Encoder-decoder backbone (whisper family).

The audio frontend is a STUB per the brief: `input_specs()` supplies
precomputed frame embeddings (B, frames, d_model) in place of the conv
front end + mel spectrogram.  Encoder = non-causal attention blocks;
decoder = causal self-attention + cross-attention + MLP, scanned over
layers like the decoder-only path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import base as B
from repro.models import attention as A
from repro.models.layers import (
    embed, embed_init, mlp, mlp_init, rmsnorm, rmsnorm_init, truncated_normal,
    unembed,
)
from repro.parallel.sharding import hint


def _enc_block_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {"ln1": rmsnorm_init(cfg.d_model, dtype),
            "attn": A.attn_init(ks[0], cfg, dtype),
            "ln2": rmsnorm_init(cfg.d_model, dtype),
            "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype)}


def _dec_block_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {"ln1": rmsnorm_init(cfg.d_model, dtype),
            "self_attn": A.attn_init(ks[0], cfg, dtype),
            "ln_x": rmsnorm_init(cfg.d_model, dtype),
            "cross_attn": A.attn_init(ks[1], cfg, dtype),
            "ln2": rmsnorm_init(cfg.d_model, dtype),
            "mlp": mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype)}


def init_encdec(cfg: B.ArchConfig, key, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    enc = [_enc_block_init(k, cfg, dtype) for k in enc_keys]
    dec = [_dec_block_init(k, cfg, dtype) for k in dec_keys]
    return {
        "embed": embed_init(ks[2], cfg.vocab_size, cfg.d_model, dtype),
        "pos_enc": truncated_normal(ks[3], (cfg.encoder_frames,
                                            cfg.d_model), dtype, 0.02),
        "enc": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "dec": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "enc_norm": rmsnorm_init(cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }


def _scan_or_unroll(block, carry, stacked, unroll, with_ys=False):
    if not unroll:
        return jax.lax.scan(block, carry, stacked)
    n = jax.tree.leaves(stacked)[0].shape[0]
    ys = []
    for g in range(n):
        carry, y = block(carry, jax.tree.map(lambda a, g=g: a[g], stacked))
        ys.append(y)
    if with_ys:
        return carry, jax.tree.map(lambda *xs: jnp.stack(xs), *ys)
    return carry, None


def encode(params, cfg, frames):
    """frames (B, F, d) stub features -> (B, F, d) encoder states."""
    x = frames + params["pos_enc"][None, : frames.shape[1]]
    x = hint(x, "dp", None, None)

    def block(x, bp):
        h, _ = A.attention_prefill(
            bp["attn"], rmsnorm(bp["ln1"], x, cfg.norm_eps), cfg,
            causal=False)
        x = x + h
        x = x + mlp(bp["mlp"], rmsnorm(bp["ln2"], x, cfg.norm_eps),
                    cfg.mlp_kind)
        return hint(x, "dp", None, None), None

    x, _ = _scan_or_unroll(block, x, params["enc"], cfg.unroll_groups)
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _cross_kv(bp, enc_states, cfg):
    k = jnp.einsum("bfd,dhk->bfhk", enc_states, bp["cross_attn"]["wk"])
    v = jnp.einsum("bfd,dhk->bfhk", enc_states, bp["cross_attn"]["wv"])
    if cfg.qkv_bias:
        k = k + bp["cross_attn"]["bk"]
        v = v + bp["cross_attn"]["bv"]
    return k, v


def forward(params, cfg: B.ArchConfig, tokens, frames):
    """Teacher-forced seq2seq forward -> (logits, aux)."""
    enc_states = encode(params, cfg, frames)
    x = embed(params["embed"], tokens) * cfg.d_model ** 0.5
    x = hint(x, "dp", None, None)

    def block(x, bp):
        h, _ = A.attention_prefill(
            bp["self_attn"], rmsnorm(bp["ln1"], x, cfg.norm_eps), cfg)
        x = x + h
        xq = rmsnorm(bp["ln_x"], x, cfg.norm_eps)
        kv = _cross_kv(bp, enc_states, cfg)
        # cross-attn: no positional rotation (positions=0 -> rope identity)
        h, _ = A.attention_prefill(
            bp["cross_attn"], xq, cfg, causal=False, kv=kv,
            positions=jnp.zeros(xq.shape[:2], jnp.int32))
        x = x + h
        x = x + mlp(bp["mlp"], rmsnorm(bp["ln2"], x, cfg.norm_eps),
                    cfg.mlp_kind)
        return hint(x, "dp", None, None), None

    x, _ = _scan_or_unroll(block, x, params["dec"], cfg.unroll_groups)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x, True)
    aux = {"moe_aux_loss": jnp.float32(0.0), "moe_dropped": jnp.int32(0)}
    return logits, aux


def init_caches(cfg: B.ArchConfig, batch: int, seq_len: int,
                dtype=jnp.float32):
    caches = [A.init_cache(cfg, batch, seq_len, dtype)
              for _ in range(cfg.n_layers)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *caches)


def decode_step(params, cfg: B.ArchConfig, token, caches, enc_states):
    """One decoder token with cached self-attn + cross-attn to enc_states."""
    x = embed(params["embed"], token) * cfg.d_model ** 0.5

    def block(x, inp):
        bp, cache = inp
        h, cache = A.attention_decode(
            bp["self_attn"], rmsnorm(bp["ln1"], x, cfg.norm_eps), cfg,
            cache)
        x = x + h
        xq = rmsnorm(bp["ln_x"], x, cfg.norm_eps)
        kv = _cross_kv(bp, enc_states, cfg)
        # cross-attn: no positional rotation (positions=0 -> rope identity)
        h, _ = A.attention_prefill(
            bp["cross_attn"], xq, cfg, causal=False, kv=kv,
            positions=jnp.zeros(xq.shape[:2], jnp.int32))
        x = x + h
        x = x + mlp(bp["mlp"], rmsnorm(bp["ln2"], x, cfg.norm_eps),
                    cfg.mlp_kind)
        return x, cache

    x, new_caches = _scan_or_unroll(block, x, (params["dec"], caches),
                                    cfg.unroll_groups, with_ys=True)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x, True)
    return logits, new_caches
