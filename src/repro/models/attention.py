"""GQA attention: init, prefill (flash kernel on TPU), and cached decode.

Decode processes ONE new token against a (B, Hkv, S, D) KV cache — O(S)
work, expressed as dense einsums against the cache (no kernel needed: the
op is bandwidth-bound reading the cache once).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention
from repro.models.layers import rope, truncated_normal


class KVCache(NamedTuple):
    k: jnp.ndarray    # (B, Hkv, S, D)
    v: jnp.ndarray    # (B, Hkv, S, D)
    length: jnp.ndarray  # int32[] valid prefix length


def attn_init(key, cfg, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = d ** -0.5
    p = {
        "wq": truncated_normal(k1, (d, H, hd), dtype, s),
        "wk": truncated_normal(k2, (d, Hkv, hd), dtype, s),
        "wv": truncated_normal(k3, (d, Hkv, hd), dtype, s),
        "wo": truncated_normal(k4, (H, hd, d), dtype, (H * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((Hkv, hd), dtype)
        p["bv"] = jnp.zeros((Hkv, hd), dtype)
    return p


def _qkv(p, x, cfg, positions):
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_prefill(p, x, cfg, *, window: Optional[int] = None,
                      causal: bool = True, positions=None,
                      kv: Optional[tuple] = None):
    """x (B,T,d) -> (B,T,d).  kv overrides self-kv for cross-attention."""
    from repro.models.chunked_attention import chunked_attention

    B, T, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    q, k, v = _qkv(p, x, cfg, positions)
    if kv is not None:
        k, v = kv
    # (B,T,H,D) -> (B,H,T,D)
    t = lambda a: a.transpose(0, 2, 1, 3)
    if jax.default_backend() == "tpu":
        # Pallas flash kernel (kernels/flash_attention)
        o = flash_attention(t(q), t(k), t(v), causal=causal, window=window,
                            sm_scale=cfg.head_dim ** -0.5)
    else:
        # flash-equivalent chunked XLA path (same working-set structure;
        # what the dry-run lowers — see chunked_attention.py)
        o = chunked_attention(
            t(q), t(k), t(v), causal=causal, window=window,
            sm_scale=cfg.head_dim ** -0.5,
            chunk=min(cfg.attn_chunk, k.shape[1]),
            unroll=cfg.unroll_groups,
            causal_skip=cfg.attn_causal_skip and cfg.unroll_groups)
    o = o.transpose(0, 2, 1, 3)  # (B,T,H,D)
    return jnp.einsum("bthk,hkd->btd", o, p["wo"]), (k, v)


def attention_decode(p, x, cfg, cache: KVCache, *,
                     window: Optional[int] = None):
    """x (B,1,d) one new token; returns (out (B,1,d), new cache)."""
    B, _, _ = x.shape
    S = cache.k.shape[2]
    pos = jnp.broadcast_to(cache.length[None], (B, 1))
    q, k_new, v_new = _qkv(p, x, cfg, pos)

    # append at position `length` (static-shape dynamic-index update)
    k = jax.lax.dynamic_update_slice(
        cache.k, k_new.transpose(0, 2, 1, 3),
        (0, 0, cache.length, 0))
    v = jax.lax.dynamic_update_slice(
        cache.v, v_new.transpose(0, 2, 1, 3),
        (0, 0, cache.length, 0))

    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    qh = q[:, 0]                      # (B,H,D)
    group = H // Hkv
    qg = qh.reshape(B, Hkv, group, cfg.head_dim)
    s = jnp.einsum("bngd,bnsd->bngs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * cfg.head_dim ** -0.5
    kpos = jnp.arange(S)
    mask = kpos[None, :] <= cache.length
    if window is not None:
        mask &= kpos[None, :] > cache.length - window
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngs,bnsd->bngd", w, v.astype(jnp.float32))
    o = o.reshape(B, 1, H, cfg.head_dim).astype(x.dtype)
    out = jnp.einsum("bthk,hkd->btd", o, p["wo"])
    return out, KVCache(k=k, v=v, length=cache.length + 1)


def init_cache(cfg, batch, seq_len, dtype, n_kv_heads=None):
    Hkv = n_kv_heads or cfg.n_kv_heads
    shape = (batch, Hkv, seq_len, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   length=jnp.int32(0))
