"""State-space / recurrent mixers: Mamba2 (SSD, chunked) and xLSTM blocks.

Mamba2 follows the SSD formulation: per-head scalar decay A, data-dependent
dt/B/C; chunked computation (quadratic within a chunk via the decay-masked
kernel matrix, linear state carry between chunks) — the structure that maps
onto MXU matmuls instead of a length-T scan.

mLSTM is implemented in the same chunked linear-attention form (matrix
memory with exponential forget/input gates); sLSTM is genuinely recurrent
(scalar memory with recurrent gate connections) and runs as a lax.scan over
time, which is faithful to its definition.

Decode paths are single-step recurrences against a small carried state —
O(1) per token, the reason these families run the long_500k shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import truncated_normal

CONV_K = 4  # mamba depthwise conv width


# ---------------------------------------------------------------------------
# Mamba2
# ---------------------------------------------------------------------------
def mamba2_init(key, cfg, dtype):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    P = 64                       # SSD head dim
    H = d_in // P
    N = cfg.ssm_state
    ks = jax.random.split(key, 6)
    return {
        "in_proj": truncated_normal(ks[0], (d, 2 * d_in + 2 * N + H),
                                    dtype, d ** -0.5),
        "conv_w": truncated_normal(ks[1], (CONV_K, d_in), dtype, 0.5),
        "A_log": jnp.zeros((H,), jnp.float32) + jnp.log(
            jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), dtype),
        "out_proj": truncated_normal(ks[2], (d_in, d), dtype, d_in ** -0.5),
    }


def _split_proj(z, d_in, N, H):
    xz, gate = z[..., :d_in], z[..., d_in:2 * d_in]
    Bc = z[..., 2 * d_in:2 * d_in + N]
    Cc = z[..., 2 * d_in + N:2 * d_in + 2 * N]
    dt = z[..., 2 * d_in + 2 * N:]
    return xz, gate, Bc, Cc, dt


def _causal_conv(x, w, state=None):
    """Depthwise causal conv; x (B,T,d_in), w (K,d_in).
    state (B,K-1,d_in) holds the trailing context for decode."""
    if state is None:
        pad = jnp.zeros((x.shape[0], CONV_K - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(CONV_K))
    new_state = xp[:, -(CONV_K - 1):]
    return out, new_state


def mamba2_apply(p, x, cfg, *, chunk=None):
    """Chunked SSD forward; x (B,T,d) -> (B,T,d).  T % chunk == 0."""
    B, T, d = x.shape
    d_in = cfg.ssm_expand * d
    N, P = cfg.ssm_state, 64
    H = d_in // P
    L = min(chunk or cfg.ssm_chunk, T)
    assert T % L == 0, (T, L)
    nC = T // L

    z = jnp.einsum("btd,de->bte", x, p["in_proj"])
    xz, gate, Bc, Cc, dt = _split_proj(z, d_in, N, H)
    xz, _ = _causal_conv(xz, p["conv_w"])
    xz = jax.nn.silu(xz)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,T,H)
    A = -jnp.exp(p["A_log"])                                     # (H,)

    xh = xz.reshape(B, nC, L, H, P)
    Bch = Bc.reshape(B, nC, L, N).astype(jnp.float32)
    Cch = Cc.reshape(B, nC, L, N).astype(jnp.float32)
    dth = dt.reshape(B, nC, L, H)

    da = dth * A                          # (B,nC,L,H) log-decay increments
    cs = jnp.cumsum(da, axis=2)           # within-chunk cumulative
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]   # (B,nC,L,L,H)
    tri = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)

    # intra-chunk: y[t] = sum_u C_t.B_u decay[t,u] dt_u x_u
    cb = jnp.einsum("bctn,bcun->bctu", Cch, Bch)         # (B,nC,L,L)
    kmat = cb[..., None] * decay                          # (B,nC,L,L,H)
    xdt = xh.astype(jnp.float32) * dth[..., None]         # (B,nC,L,H,P)
    y_intra = jnp.einsum("bctuh,bcuhp->bcthp", kmat, xdt)

    # inter-chunk state carry: h (B,H,P,N)
    chunk_decay = jnp.exp(cs[:, :, -1])                   # (B,nC,H)
    # state contribution of each chunk: sum_u exp(cs_L - cs_u) dt_u x_u B_u^T
    w_u = jnp.exp(cs[:, :, -1:, :] - cs)                  # (B,nC,L,H)
    dstate = jnp.einsum("bcuh,bcuhp,bcun->bchpn", w_u * dth, xh.astype(
        jnp.float32), Bch)

    def carry(h, inp):
        cd, ds = inp                                      # (B,H) , (B,H,P,N)
        h_new = h * cd[..., None, None] + ds
        return h_new, h                                   # emit PRE-state

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    _, h_in = jax.lax.scan(
        carry, h0, (chunk_decay.transpose(1, 0, 2),
                    dstate.transpose(1, 0, 2, 3, 4)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)                  # (B,nC,H,P,N)

    ydec = jnp.exp(cs)                                    # (B,nC,L,H)
    y_inter = jnp.einsum("bctn,bchpn,bcth->bcthp", Cch, h_in, ydec)

    y = (y_intra + y_inter).reshape(B, T, H, P)
    y = y + xz.reshape(B, T, H, P).astype(jnp.float32) * p["D"][..., None]
    y = y.reshape(B, T, d_in)
    # gated RMSNorm (mamba2 norm-before-out)
    y = y * jax.nn.silu(gate.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * p["norm_scale"].astype(jnp.float32)
    return jnp.einsum("bte,ed->btd", y.astype(x.dtype), p["out_proj"])


def mamba2_init_cache(cfg, batch, dtype):
    d_in = cfg.ssm_expand * cfg.d_model
    P = 64
    H = d_in // P
    return {
        "h": jnp.zeros((batch, H, P, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, CONV_K - 1, d_in), dtype),
    }


def mamba2_decode(p, x, cfg, cache):
    """Single-token step; x (B,1,d)."""
    B, _, d = x.shape
    d_in = cfg.ssm_expand * d
    N, P = cfg.ssm_state, 64
    H = d_in // P
    z = jnp.einsum("btd,de->bte", x, p["in_proj"])
    xz, gate, Bc, Cc, dt = _split_proj(z, d_in, N, H)
    xz, conv_state = _causal_conv(xz, p["conv_w"], cache["conv"])
    xz = jax.nn.silu(xz)[:, 0]                            # (B,d_in)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)                                   # (B,H)
    xh = xz.reshape(B, H, P).astype(jnp.float32)
    Bc1 = Bc[:, 0].astype(jnp.float32)                    # (B,N)
    Cc1 = Cc[:, 0].astype(jnp.float32)
    h = cache["h"] * a[..., None, None] + jnp.einsum(
        "bhp,bn,bh->bhpn", xh, Bc1, dt)
    y = jnp.einsum("bn,bhpn->bhp", Cc1, h) + xh * p["D"][..., None]
    y = y.reshape(B, 1, d_in)
    y = y * jax.nn.silu(gate.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * p["norm_scale"].astype(jnp.float32)
    out = jnp.einsum("bte,ed->btd", y.astype(x.dtype), p["out_proj"])
    return out, {"h": h, "conv": conv_state}


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory, chunked-parallel) and sLSTM (recurrent scan)
# ---------------------------------------------------------------------------
def mlstm_init(key, cfg, dtype):
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    ks = jax.random.split(key, 5)
    s = d ** -0.5
    return {
        "wq": truncated_normal(ks[0], (d, H, hd), dtype, s),
        "wk": truncated_normal(ks[1], (d, H, hd), dtype, s),
        "wv": truncated_normal(ks[2], (d, H, hd), dtype, s),
        "wif": truncated_normal(ks[3], (d, 2 * H), jnp.float32, s),
        "wo": truncated_normal(ks[4], (H, hd, d), dtype, s),
        "f_bias": jnp.full((H,), 3.0, jnp.float32),
    }


def mlstm_apply(p, x, cfg):
    """Stabilized matrix-LSTM in quadratic (within-sequence) form.

    D[t,u] = exp(sum_{s<=t} log f_s - sum_{s<=u} log f_s + log i_u); the
    full-sequence quadratic form is fine at xLSTM scale (T<=4k train); the
    decode path is the O(1) recurrence.
    """
    B, T, d = x.shape
    H = cfg.n_heads
    hd = d // H
    q = jnp.einsum("btd,dhk->bhtk", x, p["wq"]) * hd ** -0.5
    k = jnp.einsum("btd,dhk->bhtk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bhtk", x, p["wv"])
    g = jnp.einsum("btd,dh->bth", x.astype(jnp.float32), p["wif"])
    i_pre, f_pre = g[..., :H], g[..., H:] + p["f_bias"]
    logf = jax.nn.log_sigmoid(f_pre).transpose(0, 2, 1)   # (B,H,T)
    logi = i_pre.transpose(0, 2, 1)                        # (B,H,T)
    cf = jnp.cumsum(logf, axis=-1)
    # log D[t,u] = cf[t] - cf[u] + logi[u]  (u <= t)
    logD = cf[:, :, :, None] - cf[:, :, None, :] + logi[:, :, None, :]
    tri = jnp.tril(jnp.ones((T, T), bool))
    logD = jnp.where(tri, logD, -jnp.inf)
    m = jnp.max(logD, axis=-1, keepdims=True)              # stabilizer
    m = jnp.maximum(m, -1e30)
    Dm = jnp.exp(logD - m)
    s = jnp.einsum("bhtk,bhuk->bhtu", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * Dm
    norm = jnp.maximum(jnp.abs(jnp.sum(s, axis=-1, keepdims=True)),
                       jnp.exp(-m))
    y = jnp.einsum("bhtu,bhuk->bhtk", s / norm, v.astype(jnp.float32))
    y = y.transpose(0, 2, 1, 3).astype(x.dtype)            # (B,T,H,hd)
    return jnp.einsum("bthk,hkd->btd", y, p["wo"])


def mlstm_init_cache(cfg, batch, dtype):
    H = cfg.n_heads
    hd = cfg.d_model // H
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def mlstm_decode(p, x, cfg, cache):
    B, _, d = x.shape
    H = cfg.n_heads
    hd = d // H
    q = jnp.einsum("btd,dhk->bhk", x[:, :1], p["wq"]) * hd ** -0.5
    k = jnp.einsum("btd,dhk->bhk", x[:, :1], p["wk"])
    v = jnp.einsum("btd,dhk->bhk", x[:, :1], p["wv"])
    g = jnp.einsum("bd,dh->bh", x[:, 0].astype(jnp.float32), p["wif"])
    i_pre, f_pre = g[..., :H], g[..., H:] + p["f_bias"]
    logf = jax.nn.log_sigmoid(f_pre)
    logi = i_pre
    m_new = jnp.maximum(logf + cache["m"], logi)
    fs = jnp.exp(logf + cache["m"] - m_new)[..., None]
    is_ = jnp.exp(logi - m_new)[..., None]
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    C = cache["C"] * fs[..., None] + is_[..., None] * \
        jnp.einsum("bhk,bhv->bhkv", kf, vf)
    n = cache["n"] * fs + is_ * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhk,bhkv->bhv", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n)),
                      jnp.exp(-m_new))[..., None]
    y = (num / den).astype(x.dtype)                        # (B,H,hd)
    out = jnp.einsum("bhk,hkd->bd", y, p["wo"])[:, None]
    return out, {"C": C, "n": n, "m": m_new}


def slstm_init(key, cfg, dtype):
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    ks = jax.random.split(key, 3)
    s = d ** -0.5
    return {
        "wx": truncated_normal(ks[0], (d, H, 4 * hd), dtype, s),
        "wr": truncated_normal(ks[1], (H, hd, 4 * hd), dtype, hd ** -0.5),
        "bias": jnp.zeros((H, 4 * hd), jnp.float32),
        "wo": truncated_normal(ks[2], (H, hd, d), dtype, s),
    }


def _slstm_cell(p, gx, state):
    """One sLSTM step.  gx (B,H,4*hd) precomputed input projection."""
    c, n, m, h = state
    rec = jnp.einsum("bhk,hkg->bhg", h, p["wr"]).astype(jnp.float32)
    g = gx.astype(jnp.float32) + rec + p["bias"]
    hd = h.shape[-1]
    zt = jnp.tanh(g[..., :hd])
    i_pre = g[..., hd:2 * hd]
    f_pre = g[..., 2 * hd:3 * hd]
    o = jax.nn.sigmoid(g[..., 3 * hd:])
    m_new = jnp.maximum(f_pre + m, i_pre)
    i = jnp.exp(i_pre - m_new)
    f = jnp.exp(f_pre + m - m_new)
    c_new = f * c + i * zt
    n_new = jnp.maximum(f * n + i, 1e-6)
    h_new = (o * c_new / n_new).astype(h.dtype)
    return (c_new, n_new, m_new, h_new)


def slstm_apply(p, x, cfg):
    B, T, d = x.shape
    H = cfg.n_heads
    hd = d // H
    gx = jnp.einsum("btd,dhg->bthg", x, p["wx"])           # (B,T,H,4hd)
    state0 = slstm_init_cache(cfg, B, x.dtype)

    def step(state, gxt):
        s = _slstm_cell(p, gxt, state)
        return s, s[3]

    _, hs = jax.lax.scan(step, state0, gx.transpose(1, 0, 2, 3))
    hs = hs.transpose(1, 0, 2, 3)                          # (B,T,H,hd)
    return jnp.einsum("bthk,hkd->btd", hs, p["wo"])


def slstm_init_cache(cfg, batch, dtype):
    H = cfg.n_heads
    hd = cfg.d_model // H
    z = lambda: jnp.zeros((batch, H, hd), jnp.float32)
    return (z(), z(), jnp.full((batch, H, hd), -30.0, jnp.float32),
            jnp.zeros((batch, H, hd), dtype))


def slstm_decode(p, x, cfg, cache):
    gx = jnp.einsum("btd,dhg->bhg", x[:, :1], p["wx"])
    state = _slstm_cell(p, gx, cache)
    out = jnp.einsum("bhk,hkd->bd", state[3], p["wo"])[:, None]
    return out, state
