"""Model substrate: one composable backbone covering the 10 assigned archs."""
