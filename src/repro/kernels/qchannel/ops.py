"""Jitted public wrapper: pads/reshapes flat photon batches to VPU tiles and
dispatches to the Pallas kernel (TPU) or the jnp oracle (CPU/GPU).

The PDES ARRIVE handler and the benchmarks call `transmit_measure`; on this
CPU-only container the oracle path runs in production while the kernel is
validated in interpret mode by tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.qchannel.kernel import LANES, qchannel_2d
from repro.kernels.qchannel.ref import qchannel_ref


def _pad_to_tiles(x, rows, fill):
    n = x.shape[0]
    pad = rows * LANES - n
    return jnp.pad(x, (0, pad), constant_values=fill).reshape(rows, LANES)


def transmit_measure(uid, loss_p, bit, basis, *, use_kernel: bool = None,
                     interpret: bool = False):
    """Flat [N] photon batch -> (detected, rx_basis, outcome) int32[N]."""
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if not use_kernel:
        return qchannel_ref(uid, loss_p, bit, basis)

    n = uid.shape[0]
    rows = max(8, -(-n // LANES))
    rows += (-rows) % 8  # sublane multiple
    u = _pad_to_tiles(uid.astype(jnp.uint32), rows, 0)
    lp = _pad_to_tiles(loss_p.astype(jnp.float32), rows, 0.0)
    b = _pad_to_tiles(bit.astype(jnp.int32), rows, 0)
    ba = _pad_to_tiles(basis.astype(jnp.int32), rows, 0)
    det, rx, out = qchannel_2d(u, lp, b, ba, interpret=interpret)
    flat = lambda x: x.reshape(-1)[:n]
    return flat(det), flat(rx), flat(out)
