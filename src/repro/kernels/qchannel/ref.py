"""Pure-jnp oracle for the qchannel kernel (bit-exact, integer math)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import rng


@jax.jit
def qchannel_ref(uid, loss_p, bit, basis):
    """uid uint32[N], loss_p float32[N], bit/basis int32[N] ->
    (detected, rx_basis, outcome) int32[N]."""
    detected = ~rng.bernoulli(uid, rng.SALT_LOSS, loss_p)
    rx_basis = rng.rand_bit(uid, rng.SALT_RX_BASIS)
    flip = rng.rand_bit(uid, rng.SALT_FLIP)
    outcome = jnp.where(rx_basis == basis, bit, flip)
    return detected.astype(jnp.int32), rx_basis, outcome
