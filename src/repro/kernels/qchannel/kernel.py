"""Pallas TPU kernel: batched quantum-channel transmission + measurement.

The paper's workload analysis (obs. #1) finds quantum-channel events
dominant in both count and execution time — this is the PDES hot spot.  One
kernel call processes a whole wave of photons: loss sampling, receiver basis
choice, and BB84 measurement, all from the counter-based RNG (bit-exact with
the pure-jnp oracle in ref.py since everything is integer math).

Layout: photon batches are shaped (rows, 128) to match the VPU lane width;
the grid tiles rows in blocks of BLOCK_ROWS (8-row multiples for sublanes).
All five tensors for a block live in VMEM: 5 * BLOCK_ROWS * 128 * 4 B =
1.3 MiB at BLOCK_ROWS=512 — comfortably inside the ~16 MiB VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import rng

BLOCK_ROWS = 512
LANES = 128


def _qchannel_kernel(uid_ref, loss_ref, bit_ref, basis_ref,
                     detected_ref, rx_basis_ref, outcome_ref):
    uid = uid_ref[...]
    loss_p = loss_ref[...]
    bit = bit_ref[...]
    basis = basis_ref[...]

    detected = ~rng.bernoulli(uid, rng.SALT_LOSS, loss_p)
    rx_basis = rng.rand_bit(uid, rng.SALT_RX_BASIS)
    flip = rng.rand_bit(uid, rng.SALT_FLIP)
    outcome = jnp.where(rx_basis == basis, bit, flip)

    detected_ref[...] = detected.astype(jnp.int32)
    rx_basis_ref[...] = rx_basis
    outcome_ref[...] = outcome


@functools.partial(jax.jit, static_argnames=("interpret",))
def qchannel_2d(uid, loss_p, bit, basis, *, interpret: bool = False):
    """Core pallas_call on (rows, 128)-shaped inputs (rows % 8 == 0)."""
    rows = uid.shape[0]
    bm = min(BLOCK_ROWS, rows)
    grid = (pl.cdiv(rows, bm),)
    spec = pl.BlockSpec((bm, LANES), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct((rows, LANES), jnp.int32)] * 3
    return pl.pallas_call(
        _qchannel_kernel,
        grid=grid,
        in_specs=[spec] * 4,
        out_specs=[spec] * 3,
        out_shape=out_shape,
        interpret=interpret,
    )(uid, loss_p, bit, basis)
