"""Public flash-attention wrapper: pads to block multiples, dispatches to
the Pallas kernel on TPU or the dense oracle elsewhere."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_padded
from repro.kernels.flash_attention.ref import attention_ref


def _pad_axis(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    sm_scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    use_kernel: bool = None, interpret: bool = False):
    """q (B,H,T,D), k/v (B,Hkv,S,D) -> (B,H,T,D)."""
    if sm_scale is None:
        sm_scale = q.shape[-1] ** -0.5
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if not use_kernel:
        return attention_ref(q, k, v, sm_scale=sm_scale, causal=causal,
                             window=window)
    T, S = q.shape[2], k.shape[2]
    qp = _pad_axis(q, 2, block_q)
    kp = _pad_axis(k, 2, block_k)
    vp = _pad_axis(v, 2, block_k)
    out = flash_attention_padded(
        qp, kp, vp, sm_scale=sm_scale, causal=causal, window=window,
        q_len=T, kv_len=S, block_q=block_q, block_k=block_k,
        interpret=interpret)
    return out[:, :, :T, :]
