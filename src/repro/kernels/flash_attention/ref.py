"""Pure-jnp oracle: dense softmax attention with the same mask semantics."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("causal", "window", "sm_scale"))
def attention_ref(q, k, v, *, sm_scale: float, causal: bool = True,
                  window=None):
    """q (B,H,T,D), k/v (B,Hkv,S,D) -> (B,H,T,D); GQA via head repeat."""
    B, H, T, D = q.shape
    Hkv = k.shape[1]
    if Hkv != H:
        rep = H // Hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    qpos = jnp.arange(T)[:, None]
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((T, k.shape[2]), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
