"""Pallas TPU flash attention (forward) with GQA, causal + sliding window.

Blocked online-softmax: grid (B, H, Tq/bq, Tk/bk); the innermost grid axis
walks KV blocks ("arbitrary" semantics) accumulating into VMEM scratch
(acc, running max m, running sum l).  Block shapes keep the MXU fed:
(bq, d_head) x (d_head, bk) matmuls with bq = bk = 128 by default and
d_head padded to a 128 multiple by the ops.py wrapper.

VMEM per grid cell at bq=bk=128, D=128: q/k/v blocks 3*64 KiB + acc 64 KiB
+ m/l 2*64 KiB (broadcast across lanes, TPU-friendly layout) ~ 0.4 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

try:  # TPU compiler params are optional (ignored in interpret mode)
    from jax.experimental.pallas import tpu as pltpu
    _SCRATCH = pltpu.VMEM
    _COMPILER_PARAMS = dict(compiler_params=pltpu.CompilerParams(
        dimension_semantics=("parallel", "parallel", "parallel",
                             "arbitrary")))
except Exception:  # pragma: no cover
    pltpu = None
    _SCRATCH = None
    _COMPILER_PARAMS = {}

NEG_INF = -1e30
LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  sm_scale, causal, window, bq, bk, q_len, kv_len, grid_k):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale

    qpos = iq * bq + lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = (qpos < q_len) & (kpos < kv_len)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, :1]
    l_prev = l_ref[:, :1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)

    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == grid_k - 1)
    def _finish():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked (padding) rows
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "sm_scale", "block_q", "block_k",
                     "q_len", "kv_len", "interpret"))
def flash_attention_padded(
    q, k, v, *, sm_scale: float, causal: bool, window,
    q_len: int, kv_len: int, block_q: int = 128, block_k: int = 128,
    interpret: bool = False,
):
    """Core call; q (B,H,Tp,D), k/v (B,Hkv,Sp,D) with Tp%bq == Sp%bk == 0."""
    B, H, Tp, D = q.shape
    _, Hkv, Sp, _ = k.shape
    assert H % Hkv == 0
    group = H // Hkv
    bq, bk = min(block_q, Tp), min(block_k, Sp)
    grid = (B, H, Tp // bq, Sp // bk)

    kern = functools.partial(
        _flash_kernel, sm_scale=sm_scale, causal=causal, window=window,
        bq=bq, bk=bk, q_len=q_len, kv_len=kv_len, grid_k=grid[3])

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Tp, D), q.dtype),
        scratch_shapes=[
            _SCRATCH((bq, D), jnp.float32),
            _SCRATCH((bq, LANES), jnp.float32),
            _SCRATCH((bq, LANES), jnp.float32),
        ],
        interpret=interpret,
        **(_COMPILER_PARAMS if not interpret else {}),
    )(q, k, v)
