"""Pure-jnp oracle for the event_select kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import TIME_MAX


@jax.jit
def event_select_ref(time, valid, epoch_end):
    """Stable argsort of masked timestamps == lexicographic (ts, slot)."""
    key = jnp.where(valid & (time < epoch_end), time, TIME_MAX)
    order = jnp.argsort(key, stable=True).astype(jnp.int32)
    count = jnp.sum((key != TIME_MAX).astype(jnp.int32))
    return order, count
