"""Pallas TPU kernel: epoch-window event selection via in-VMEM bitonic sort.

The SeQUeNCe scheduler's hot loop is "pop every event with ts < epoch_end in
timestamp order".  On TPU we fuse the window mask, the (timestamp, slot)
lexicographic sort, and the selected-count reduction into one kernel over
the shard's whole event pool held in VMEM (8192 events * 2 arrays * 4 B =
64 KiB — VMEM is the natural home for a pool this size; the sort never
touches HBM).

The sort is a classic bitonic network: for pool capacity 2^m there are
m(m+1)/2 compare-exchange stages, each expressed as a static reshape to
(cap/2j, 2, j) and a vectorized lexicographic min/max — no data-dependent
control flow, which is exactly what the TPU wants.  Ties break on slot
index, matching jnp.argsort(stable=True) in ref.py bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.types import TIME_MAX


def _bitonic_stage(key, idx, cap, k, j):
    pk = key.reshape(cap // (2 * j), 2, j)
    pi = idx.reshape(cap // (2 * j), 2, j)
    a_k, b_k = pk[:, 0, :], pk[:, 1, :]
    a_i, b_i = pi[:, 0, :], pi[:, 1, :]
    # direction of flat element i depends on (i & k); within a row r all
    # elements share it because i = r*2j + s*j + t and s*j + t < 2j <= k.
    rows = lax.broadcasted_iota(jnp.int32, (cap // (2 * j), j), 0)
    dir_up = ((rows * (2 * j)) & k) == 0
    a_lt = (a_k < b_k) | ((a_k == b_k) & (a_i < b_i))
    keep = a_lt == dir_up
    na_k = jnp.where(keep, a_k, b_k)
    nb_k = jnp.where(keep, b_k, a_k)
    na_i = jnp.where(keep, a_i, b_i)
    nb_i = jnp.where(keep, b_i, a_i)
    key = jnp.stack([na_k, nb_k], axis=1).reshape(cap)
    idx = jnp.stack([na_i, nb_i], axis=1).reshape(cap)
    return key, idx


def _event_select_kernel(time_ref, valid_ref, end_ref, order_ref, count_ref,
                         *, cap: int):
    t = time_ref[...].reshape(cap)
    v = valid_ref[...].reshape(cap) != 0
    end = end_ref[0, 0]
    key = jnp.where(v & (t < end), t, TIME_MAX)
    idx = lax.broadcasted_iota(jnp.int32, (cap, 1), 0).reshape(cap)

    k = 2
    while k <= cap:
        j = k // 2
        while j >= 1:
            key, idx = _bitonic_stage(key, idx, cap, k, j)
            j //= 2
        k *= 2

    order_ref[...] = idx.reshape(order_ref.shape)
    count_ref[0, 0] = jnp.sum((key != TIME_MAX).astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def event_select(time, valid, epoch_end, *, interpret: bool = False):
    """time int32[cap], valid bool[cap], epoch_end scalar ->
    (order int32[cap] — selected slots first, by (ts, slot); count int32).

    cap must be a power of two and a multiple of 1024 (rows of 128 lanes).
    """
    cap = time.shape[0]
    assert cap & (cap - 1) == 0 and cap >= 128, "capacity must be pow2>=128"
    rows = cap // 128
    t2 = time.reshape(rows, 128)
    v2 = valid.astype(jnp.int32).reshape(rows, 128)
    end2 = jnp.asarray(epoch_end, jnp.int32).reshape(1, 1)
    order, count = pl.pallas_call(
        functools.partial(_event_select_kernel, cap=cap),
        in_specs=[
            pl.BlockSpec((rows, 128), lambda: (0, 0)),
            pl.BlockSpec((rows, 128), lambda: (0, 0)),
            pl.BlockSpec((1, 1), lambda: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((rows, 128), lambda: (0, 0)),
            pl.BlockSpec((1, 1), lambda: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, 128), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(t2, v2, end2)
    return order.reshape(cap), count[0, 0]
