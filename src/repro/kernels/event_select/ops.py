"""Public wrapper for epoch-window selection.

On TPU the fused Pallas kernel keeps the pool resident in VMEM; elsewhere
the XLA stable-sort oracle runs (identical results).  The wave scheduler in
timeline.py uses mask/segment-min gating instead of a full sort — that IS
the TPU adaptation (DESIGN.md §3) — so this op serves (a) the sorted-drain
execution mode used by benchmarks to mimic SeQUeNCe's serial pop order, and
(b) as the scheduler building block a strict-priority workload would use.
"""
from __future__ import annotations

import jax

from repro.kernels.event_select.kernel import event_select
from repro.kernels.event_select.ref import event_select_ref


def sorted_window(time, valid, epoch_end, *, use_kernel: bool = None,
                  interpret: bool = False):
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        return event_select(time, valid, epoch_end, interpret=interpret)
    return event_select_ref(time, valid, epoch_end)
