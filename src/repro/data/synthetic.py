"""Deterministic synthetic token pipeline.

Every batch is a pure function of (seed, step, shard) — restart/elastic
resume is skip-ahead by construction (no iterator state to checkpoint), and
different data shards never overlap.  A markov-chain generator gives the
loss curve actual structure to learn (unlike uniform noise), which the
end-to-end training example uses to show loss descent.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "markov"   # markov | uniform


def _markov_tokens(key, shape, vocab):
    """Order-1 markov chain with a banded transition structure."""
    b, t = shape
    k1, k2 = jax.random.split(key)
    start = jax.random.randint(k1, (b,), 0, vocab, jnp.int32)
    steps = jax.random.randint(k2, (b, t), 0, 17, jnp.int32) - 8

    def step(tok, d):
        nxt = jnp.abs(tok * 31 + d) % vocab
        return nxt, nxt

    _, toks = jax.lax.scan(step, start, steps.T)
    return toks.T


def make_batch(cfg: DataConfig, step: int):
    """Global batch for `step` (host-side; sharded by the caller)."""
    key = jax.random.fold_in(jax.random.key(cfg.seed), step)
    shape = (cfg.global_batch, cfg.seq_len)
    if cfg.kind == "markov":
        toks = _markov_tokens(key, shape, cfg.vocab_size)
    else:
        toks = jax.random.randint(key, shape, 0, cfg.vocab_size, jnp.int32)
    return {"tokens": toks}


def make_shard_batch(cfg: DataConfig, step: int, shard: int, n_shards: int):
    """Per-data-shard slice, disjoint across shards, skip-ahead capable."""
    assert cfg.global_batch % n_shards == 0
    per = cfg.global_batch // n_shards
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.key(cfg.seed), step), shard)
    if cfg.kind == "markov":
        toks = _markov_tokens(key, (per, cfg.seq_len), cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (per, cfg.seq_len), 0,
                                  cfg.vocab_size, jnp.int32)
    return {"tokens": toks}
