"""Sharding rules: logical activation hints + per-parameter PartitionSpecs.

Parallelism mapping (DESIGN.md §5):
  * DP  — batch over ("pod", "data")
  * TP  — heads / ffn / vocab over "model"
  * EP  — MoE experts over "model" (falls back to ffn-dim sharding when the
          expert count does not divide the TP degree, e.g. granite's 40)
  * SP  — long-context decode shards the KV/state cache sequence dim over
          "data" (batch=1 cells)
  * ZeRO-1 — optimizer state sharded over "data" (see repro/optim/zero.py)

Activation hints are no-ops unless a mesh has been activated
(`activate_mesh`), so model code runs unchanged in single-device tests.
"""
from __future__ import annotations

import contextlib
import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE_MESH: Optional[Mesh] = None


@contextlib.contextmanager
def activate_mesh(mesh: Optional[Mesh]):
    global _ACTIVE_MESH
    prev = _ACTIVE_MESH
    _ACTIVE_MESH = mesh
    try:
        yield
    finally:
        _ACTIVE_MESH = prev


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH


def dp_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def hint(x, *spec):
    """with_sharding_constraint if a mesh is active, else identity.
    spec entries: "dp" -> ("pod","data"), "model", "data", or None."""
    mesh = _ACTIVE_MESH
    if mesh is None:
        return x
    out = []
    for s in spec:
        if s == "dp":
            out.append(dp_axes(mesh) or None)
        elif s is None or s in mesh.axis_names:
            out.append(s)
        else:
            out.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*out)))


# ---------------------------------------------------------------------------
# parameter shardings
# ---------------------------------------------------------------------------
def _divisible(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _spec_for(path: str, shape: tuple, tp: int) -> P:
    """TP PartitionSpec for one parameter leaf (no leading stack axes)."""
    name = path.split("/")[-1]
    nd = len(shape)

    def shard(i):
        return _divisible(shape[i], tp)

    if name == "table":                      # (vocab, d)
        return P("model", None) if shard(0) else P(None, None)
    if name in ("wq", "wk", "wv"):           # (d, H, hd)
        return P(None, "model", None) if shard(1) else P(None, None, None)
    if name in ("bq", "bk", "bv"):           # (H, hd)
        return P("model", None) if shard(0) else P(None, None)
    if name == "wo" and nd == 3:             # attn/xlstm (H, hd, d)
        return P("model", None, None) if shard(0) else P(None, None, None)
    if name in ("wi", "wg") and nd == 2:     # mlp (d, f)
        return P(None, "model") if shard(1) else P(None, None)
    if name == "wo" and nd == 2:             # mlp (f, d)
        return P("model", None) if shard(0) else P(None, None)
    if name in ("wi", "wg", "wo") and nd == 3 and "moe" in path:
        # moe (E, d, f) / (E, f, d): experts over model if divisible,
        # else shard the ffn dim
        if shard(0):
            return P("model", None, None)
        f_axis = 2 if name != "wo" else 1
        if _divisible(shape[f_axis], tp):
            spec = [None, None, None]
            spec[f_axis] = "model"
            return P(*spec)
        return P(None, None, None)
    if name == "router":                     # (d, E)
        return P(None, None)
    if name == "in_proj":                    # mamba (d, e)
        return P(None, "model") if shard(1) else P(None, None)
    if name == "out_proj":                   # mamba (d_in, d)
        return P("model", None) if shard(0) else P(None, None)
    if name == "conv_w":                     # (K, d_in)
        return P(None, "model") if shard(1) else P(None, None)
    if name == "wx":                         # slstm (d, H, 4hd)
        return P(None, "model", None) if shard(1) else P(None, None, None)
    if name == "wr":                         # slstm (H, hd, 4hd)
        return P("model", None, None) if shard(0) else P(None, None, None)
    if name == "wif":                        # mlstm (d, 2H)
        return P(None, None)
    if name == "bias" and nd == 2:           # slstm (H, 4hd)
        return P("model", None) if shard(0) else P(None, None)
    # scales, biases, A_log, D, dt_bias, f_bias, norm scales: replicate
    return P(*([None] * nd))


def param_specs(params, tp: int, stacked_key: str = "groups"):
    """Pytree of PartitionSpecs matching `params`.

    Leaves under the `groups`/`enc`/`dec` subtrees carry a leading
    scan-stack axis -> their spec gets None prepended.
    """
    def walk(tree, path, stacked):
        if isinstance(tree, dict):
            return {k: walk(v, f"{path}/{k}",
                            stacked or k in (stacked_key, "enc", "dec"))
                    for k, v in tree.items()}
        shape = tuple(tree.shape)
        if stacked:
            base = _spec_for(path, shape[1:], tp)
            return P(None, *base)
        return _spec_for(path, shape, tp)

    return walk(params, "", False)


def param_shardings(params, mesh: Mesh):
    tp = mesh.shape.get("model", 1)
    specs = param_specs(params, tp)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def fsdp_param_specs(params, mesh: Mesh):
    """FSDP (ZeRO-3 style): on top of TP, shard each parameter's largest
    unsharded divisible dim over the DP axes.  XLA all-gathers weights at
    use (per scan group) — params drop to bytes/(DP*TP) per chip, which is
    what fits the 110B config on 16 GB v5e chips."""
    tp = mesh.shape.get("model", 1)
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    base = param_specs(params, tp)

    def widen(p, s):
        if not dp:
            return s
        entries = list(s) + [None] * (p.ndim - len(s))
        best, best_size = None, 0
        for i, (e, n) in enumerate(zip(entries, p.shape)):
            if e is None and n % dp_size == 0 and n > best_size:
                best, best_size = i, n
        if best is not None:
            entries[best] = dp if len(dp) > 1 else dp[0]
        return P(*entries)

    return jax.tree.map(widen, params, base,
                        is_leaf=lambda x: isinstance(x, P))


def cache_specs(caches, mesh: Mesh, batch: int, seq_len: int):
    """PartitionSpecs for decode caches (stacked leading group axis).

    KV caches shard batch over DP and sequence over 'model'; batch=1
    long-context cells shard sequence over both axes (SP).  SSM/recurrent
    states shard batch over DP and heads/feature dims over 'model' where
    divisible."""
    tp = mesh.shape.get("model", 1)
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    dp_entry = (dp if len(dp) > 1 else dp[0]) if dp else None
    batch_ok = dp and batch % dp_size == 0

    def leaf_spec(path, x):
        name = None
        for p in reversed(path):
            if hasattr(p, "name"):
                name = p.name
                break
            if hasattr(p, "key"):
                name = p.key
                break
        nd = x.ndim
        if nd <= 1:      # lengths / scalars (possibly stacked)
            return P(*([None] * nd))
        e = [None] * nd
        # leading axis is the group stack; logical dims shift by +1
        b_ax = 1
        if name in ("k", "v") and nd == 5:       # (G,B,Hkv,S,D)
            if batch_ok:
                e[b_ax] = dp_entry
                if seq_len % tp == 0:
                    e[3] = "model"
            else:
                # SP: shard the long sequence over everything divisible
                if seq_len % (dp_size * tp) == 0:
                    e[3] = tuple([*dp, "model"]) if dp else "model"
                elif seq_len % tp == 0:
                    e[3] = "model"
            return P(*e)
        if batch_ok:
            e[b_ax] = dp_entry
        # shard the largest remaining divisible dim over model
        best, best_size = None, 0
        for i in range(b_ax + 1, nd):
            if x.shape[i] % tp == 0 and x.shape[i] > best_size:
                best, best_size = i, x.shape[i]
        if best is not None and tp > 1 and best_size >= tp:
            e[best] = "model"
        return P(*e)

    return jax.tree_util.tree_map_with_path(leaf_spec, caches)


def batch_sharding(mesh: Mesh, shape: tuple, *,
                   seq_axis: Optional[int] = None,
                   batch_size: Optional[int] = None):
    """Inputs: batch over dp axes; batch=1 long-context cells shard the
    sequence axis over 'data' instead (SP) when divisible."""
    ndim = len(shape)
    dp = dp_axes(mesh)
    spec = [None] * ndim
    dp_total = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    if batch_size is not None and dp and batch_size % dp_total != 0:
        if (seq_axis is not None and seq_axis < ndim
                and shape[seq_axis] % dp_total == 0 and shape[seq_axis] > 1):
            spec[seq_axis] = dp if len(dp) > 1 else dp[0]
        return NamedSharding(mesh, P(*spec))
    if dp and shape[0] % dp_total == 0:
        spec[0] = dp if len(dp) > 1 else dp[0]
    return NamedSharding(mesh, P(*spec))
