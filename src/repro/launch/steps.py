"""Step functions lowered by the dry-run and driven by the trainer/server."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import api
from repro.optim import adamw


def make_train_step(cfg: ArchConfig, lr: float = 3e-4,
                    grad_compress: bool = False):
    from repro.optim import compress as C

    def train_step(params, opt_state, batch, residual=None):
        (loss, aux), grads = jax.value_and_grad(
            api.loss_fn, has_aux=True)(params, cfg, batch)
        if grad_compress:
            grads, residual = C.apply(grads, residual)
        params, opt_state, gnorm = adamw.update(grads, opt_state, params,
                                                lr=lr)
        metrics = {"loss": loss, "gnorm": gnorm,
                   "moe_aux": aux["moe_aux_loss"],
                   "moe_dropped": aux["moe_dropped"]}
        if grad_compress:
            return params, opt_state, residual, metrics
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig):
    def prefill(params, batch):
        return api.prefill_step(params, cfg, batch)

    return prefill


def make_decode_step(cfg: ArchConfig):
    def decode(params, batch, caches):
        return api.decode_step(params, cfg, batch, caches)

    return decode
