"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

  compute    = HLO_FLOPs_per_device  / peak_bf16
  memory     = HLO_bytes_per_device  / HBM_bw
  collective = collective_bytes_per_device / link_bw

`cost_analysis()` visits while-loop (lax.scan) bodies ONCE (verified
empirically), so a deep scanned model would be undercounted.  We therefore
lower each cell at pattern_repeats R=1 and R=2, take the per-group delta,
and extrapolate affinely: total(R) = f(1) + (R-1) * (f(2) - f(1)) — exact
for homogeneous stacks.  The FULL-depth compile still runs for
memory_analysis (fit proof) and the collective schedule.

Collective bytes are parsed from the compiled per-device HLO: for every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
(+ async -start forms) we take the largest tensor in the op line as the
traffic proxy (= operand for reduce-scatter, result for all-gather, either
for all-reduce) and weight all-reduce x2 (ring reduce+broadcast phases).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

# --- TPU v5e hardware constants (per brief) ---
PEAK_BF16 = 197e12         # FLOP/s per chip
HBM_BW = 819e9             # B/s per chip
ICI_BW = 50e9              # B/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}
_SHAPE_RE = re.compile(
    r"\b(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|pred)"
    r"\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s+(?:\([^)]*\)|\S+)?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_WEIGHT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
           "all-to-all": 1.0, "collective-permute": 1.0}


def _tensor_bytes(match) -> int:
    dt, dims = match.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


_HBM_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)?\s*"
    r"(dot|convolution|gather|scatter|reduce|sort|dynamic-slice|"
    r"dynamic-update-slice)\(")


def hbm_bytes_fused(hlo_text: str) -> float:
    """Fusion-adjusted HBM-traffic estimate (the TPU memory-term input).

    The CPU backend materializes elementwise chains and f32 upcasts that a
    TPU fuses into VMEM, so raw `bytes accessed` overestimates HBM traffic
    by ~10x.  We count only ops that genuinely stream HBM on TPU: matmul /
    conv / gather / scatter / reduce / (dynamic-)slice operands+results,
    plus entry parameters once (weights already appear as dot operands;
    the parameter pass catches optimizer-state streams).  Collectives are
    accounted in their own roofline term."""
    total = 0.0
    in_entry = False
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            in_entry = True
        m = _HBM_OP_RE.search(line)
        if m:
            total += sum(_tensor_bytes(s) for s in _SHAPE_RE.finditer(line))
            continue
        if in_entry and re.search(r"=\s*\S+\s+parameter\(", line):
            sizes = [_tensor_bytes(s) for s in _SHAPE_RE.finditer(line)]
            total += max(sizes) if sizes else 0
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device collective traffic by op kind (weighted bytes)."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        sizes = [_tensor_bytes(s) for s in _SHAPE_RE.finditer(line)]
        if not sizes:
            continue
        out[kind] = out.get(kind, 0.0) + max(sizes) * _WEIGHT[kind]
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float               # per-device
    hbm_bytes: float           # per-device (fusion-adjusted, see above)
    coll_bytes: float          # per-device (weighted)
    coll_by_kind: Dict[str, float]
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float = 0.0   # 6ND(active) total, for the usefulness ratio
    raw_bytes: float = 0.0     # XLA 'bytes accessed' (CPU-backend upper bd)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound = sum; perfectly-overlapped = max.
        We report the MAX (roofline): hardware overlaps DMA/ICI/MXU."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "flops_per_dev": self.flops, "hbm_bytes_per_dev": self.hbm_bytes,
            "raw_bytes_per_dev": self.raw_bytes,
            "coll_bytes_per_dev": self.coll_bytes,
            "coll_by_kind": self.coll_by_kind,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops_total": self.model_flops,
        }


def analyze(cost: dict, hlo_text: str, n_devices: int,
            model_flops: float = 0.0) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    raw = float(cost.get("bytes accessed", 0.0))
    hbm = hbm_bytes_fused(hlo_text)
    coll = collective_bytes(hlo_text)
    coll_total = sum(coll.values())
    return RooflineTerms(
        flops=flops, hbm_bytes=hbm, coll_bytes=coll_total,
        coll_by_kind=coll,
        compute_s=flops / PEAK_BF16,
        memory_s=hbm / HBM_BW,
        collective_s=coll_total / ICI_BW,
        model_flops=model_flops,
        raw_bytes=raw,
    )


def extrapolate(t1: RooflineTerms, t2: RooflineTerms,
                repeats: int) -> RooflineTerms:
    """Affine depth extrapolation from R=1 and R=2 lowerings."""
    def ext(a, b):
        return a + (repeats - 1) * (b - a)

    kinds = set(t1.coll_by_kind) | set(t2.coll_by_kind)
    coll_by_kind = {k: ext(t1.coll_by_kind.get(k, 0.0),
                           t2.coll_by_kind.get(k, 0.0)) for k in kinds}
    flops = ext(t1.flops, t2.flops)
    hbm = ext(t1.hbm_bytes, t2.hbm_bytes)
    coll = sum(coll_by_kind.values())
    return RooflineTerms(
        flops=flops, hbm_bytes=hbm, coll_bytes=coll,
        coll_by_kind=coll_by_kind,
        compute_s=flops / PEAK_BF16,
        memory_s=hbm / HBM_BW,
        collective_s=coll / ICI_BW,
        model_flops=t1.model_flops,
        raw_bytes=ext(t1.raw_bytes, t2.raw_bytes),
    )


def count_params(cfg) -> tuple[float, float]:
    """(total, active) parameter counts from config arithmetic."""
    import jax
    import jax.numpy as jnp
    from repro.models import api

    params = jax.eval_shape(
        lambda: api.init_params(cfg, jax.random.key(0), jnp.bfloat16))
    total = sum(x.size for x in jax.tree.leaves(params))
    active = total
    if cfg.n_experts:
        # expert ffn leaves: (R, E, d, f) stacked — scale by top_k/E
        def expert_size(path, x):
            names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
            return x.size if "moe" in names and x.ndim >= 3 else 0
        import jax.tree_util as jtu
        exp = sum(jtu.tree_leaves(jtu.tree_map_with_path(expert_size,
                                                         params)))
        active = total - exp + exp * cfg.moe_top_k / cfg.n_experts
    return float(total), float(active)


def model_flops_for(cfg, shape, total: float, active: float) -> float:
    """Reference MODEL_FLOPS: 6*N*D train, 2*N*D prefill, 2*N*B decode."""
    if shape.kind == "train":
        return 6.0 * active * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * active * shape.seq_len * shape.global_batch
    return 2.0 * active * shape.global_batch  # decode: one token
