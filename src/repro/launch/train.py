"""Training launcher: ``python -m repro.launch.train --arch <id> ...``.

On a real pod this binary runs once per host (jax.distributed handles the
rest); on this container it runs single-process (optionally with a host
mesh via --host-devices, set before jax init)."""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--host-devices", type=int, default=0,
                    help="CPU host device count for a (data,1) test mesh")
    ap.add_argument("--telemetry-csv", default=None)
    args = ap.parse_args()

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices}")

    # import AFTER the device-count env var
    from repro.configs import registry as R
    from repro.launch.mesh import make_host_mesh
    from repro.runtime.trainer import TrainConfig, Trainer

    cfg = R.get_config(args.arch)
    if args.smoke:
        cfg = R.smoke_config(cfg)
    mesh = make_host_mesh(args.host_devices) if args.host_devices else None
    tc = TrainConfig(arch=cfg, steps=args.steps, lr=args.lr,
                     seq_len=args.seq_len, global_batch=args.global_batch,
                     ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    tr = Trainer(tc, mesh=mesh)
    summary = tr.train()
    if args.telemetry_csv:
        tr.timer.to_csv(args.telemetry_csv)
    print("train summary:", summary)
    return 0


if __name__ == "__main__":
    sys.exit(main())
