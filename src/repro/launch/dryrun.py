import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402  (the two lines above MUST precede any jax import)
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 16x16 (one
pod, 256 chips) and 2x16x16 (two pods, 512 chips) meshes, every assigned
architecture and input shape, plus the PDES engine itself on 256/512
timeline shards.  Emits per-cell JSON (memory analysis, cost analysis,
roofline terms) consumed by benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --pdes
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import base as BB
from repro.configs import registry as R
from repro.launch import roofline as RL
from repro.launch.mesh import make_pdes_mesh, make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step, \
    make_train_step
from repro.models import api
from repro.optim import adamw
from repro.parallel import sharding as SH

DTYPE = jnp.bfloat16
OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _depth_scaled(cfg: BB.ArchConfig, repeats: int) -> BB.ArchConfig:
    """Reduced-depth UNROLLED copy for the roofline R=1/R=2 lowerings
    (scan bodies are costed once by XLA, so deltas need straight-line
    HLO; see roofline.py)."""
    upd = dict(n_layers=len(cfg.block_pattern) * repeats,
               unroll_groups=True)
    if cfg.encoder_layers:
        upd["encoder_layers"] = repeats
    return dataclasses.replace(cfg, **upd)


def _batch_shardings(batch_specs, mesh, shape):
    def one(path, s):
        ndim = len(s.shape)
        return SH.batch_sharding(mesh, s.shape,
                                 seq_axis=1 if ndim > 1 else None,
                                 batch_size=shape.global_batch)

    return jax.tree_util.tree_map_with_path(one, batch_specs)


def lower_cell(cfg: BB.ArchConfig, shape: BB.ShapeConfig, mesh):
    """Build step fn + arg specs + shardings; return (lowered, compiled)."""
    n_dev = mesh.devices.size
    with SH.activate_mesh(mesh):
        if shape.kind == "train":
            step = make_train_step(cfg)
            params = jax.eval_shape(
                lambda: api.init_params(cfg, jax.random.key(0), DTYPE))
            opt = jax.eval_shape(adamw.init, params)
            batch = api.input_specs(cfg, shape, DTYPE)
            p_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                SH.fsdp_param_specs(params, mesh),
                is_leaf=lambda x: isinstance(x, P))
            from repro.optim.zero import zero1_shardings
            mu_sh = zero1_shardings(params, mesh)
            o_sh = adamw.AdamWState(mu=mu_sh, nu=mu_sh,
                                    count=NamedSharding(mesh, P()))
            b_sh = _batch_shardings(batch, mesh, shape)
            jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh))
            lowered = jitted.lower(params, opt, batch)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg)
            params = jax.eval_shape(
                lambda: api.init_params(cfg, jax.random.key(0), DTYPE))
            batch = api.input_specs(cfg, shape, DTYPE)
            p_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                SH.fsdp_param_specs(params, mesh),
                is_leaf=lambda x: isinstance(x, P))
            b_sh = _batch_shardings(batch, mesh, shape)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(params, batch)
        else:  # decode
            step = make_decode_step(cfg)
            params = jax.eval_shape(
                lambda: api.init_params(cfg, jax.random.key(0), DTYPE))
            batch, caches = api.input_specs(cfg, shape, DTYPE)
            p_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                SH.param_specs(params, mesh.shape.get("model", 1)),
                is_leaf=lambda x: isinstance(x, P))
            c_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                SH.cache_specs(caches, mesh, shape.global_batch,
                               shape.seq_len),
                is_leaf=lambda x: isinstance(x, P))
            b_sh = _batch_shardings(batch, mesh, shape)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh, c_sh))
            lowered = jitted.lower(params, batch, caches)
        compiled = lowered.compile()
    return lowered, compiled


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             out_dir: Path, verbose: bool = True) -> dict:
    cfg = R.get_config(arch)
    shape = R.SHAPES[shape_name]
    n_dev = mesh.devices.size
    if not api.supports_shape(cfg, shape):
        rec = dict(arch=arch, shape=shape_name, mesh=mesh_name,
                   status="skipped",
                   reason="full-attention arch: long_500k requires a "
                          "sub-quadratic serve path (DESIGN.md §4)")
        _write(rec, out_dir, arch, shape_name, mesh_name)
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: SKIPPED")
        return rec

    t0 = time.time()
    total, active = RL.count_params(cfg)
    mf = RL.model_flops_for(cfg, shape, total, active)

    # depth extrapolation lowers (R=1, R=2)
    terms12 = []
    for r in (1, 2):
        _, comp = lower_cell(_depth_scaled(cfg, r), shape, mesh)
        terms12.append(RL.analyze(comp.cost_analysis(), comp.as_text(),
                                  n_dev, mf))
    terms = RL.extrapolate(terms12[0], terms12[1], cfg.pattern_repeats)

    # full-depth compile: the actual fit/coherence proof
    lowered, compiled = lower_cell(cfg, shape, mesh)
    mem = compiled.memory_analysis()
    mem_rec = {}
    if mem is not None:
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            mem_rec[f] = getattr(mem, f, None)
    cost_full = compiled.cost_analysis()

    rec = dict(
        arch=arch, shape=shape_name, mesh=mesh_name, status="ok",
        n_devices=int(n_dev),
        params_total=total, params_active=active,
        model_flops=mf,
        roofline=terms.as_dict(),
        full_depth_cost=dict(
            flops=float(cost_full.get("flops", 0.0)),
            bytes_accessed=float(cost_full.get("bytes accessed", 0.0))),
        memory_analysis=mem_rec,
        elapsed_s=round(time.time() - t0, 1),
    )
    _write(rec, out_dir, arch, shape_name, mesh_name)
    if verbose:
        r = rec["roofline"]
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
              f"dominant={r['dominant']} compute={r['compute_s']:.3e}s "
              f"memory={r['memory_s']:.3e}s coll={r['collective_s']:.3e}s "
              f"args/dev={mem_rec.get('argument_size_in_bytes')} "
              f"({rec['elapsed_s']}s)")
    return rec


def run_pdes(n_shards: int, out_dir: Path) -> dict:
    """Dry-run the PDES engine itself on a timeline-sharded mesh."""
    from repro.core import EngineConfig, Simulator, linear_network, \
        make_partition

    t0 = time.time()
    mesh = make_pdes_mesh(n_shards)
    net = linear_network(n_routers=max(n_shards * 2, 64), n_photons=64)
    part = make_partition(net, n_shards, scheme="contiguous")
    cfg = EngineConfig(n_shards=n_shards, pool_cap=2048, qsm_cap=512,
                       outbox_cap=512, route_cap=8)
    sim = Simulator(net, part, cfg, mesh=mesh)
    lowered = sim._step.lower(sim.state, sim.lookahead, 8)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    terms = RL.analyze(cost, compiled.as_text(), n_shards)
    rec = dict(arch="pdes-qkd", shape=f"{n_shards}shards",
               mesh=f"pdes{n_shards}", status="ok",
               n_devices=n_shards, roofline=terms.as_dict(),
               elapsed_s=round(time.time() - t0, 1))
    _write(rec, out_dir, "pdes-qkd", f"{n_shards}shards", "pdes")
    print(f"[dryrun] PDES x {n_shards} shards: OK "
          f"dominant={terms.dominant} ({rec['elapsed_s']}s)")
    return rec


def _write(rec: dict, out_dir: Path, arch: str, shape: str, mesh: str):
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"{arch}__{shape}__{mesh}.json"
    path.write_text(json.dumps(rec, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--pdes", action="store_true")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()
    out_dir = Path(args.out)

    if args.pdes:
        run_pdes(256, out_dir)
        run_pdes(512, out_dir)
        return

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh()))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16",
                       make_production_mesh(multi_pod=True)))

    archs = sorted(R.ARCHS) if args.all else [args.arch]
    shapes = [s.name for s in BB.ALL_SHAPES] if args.all else [args.shape]
    failures = []
    for mesh_name, mesh in meshes:
        for arch in archs:
            for shape in shapes:
                try:
                    run_cell(arch, shape, mesh, mesh_name, out_dir)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape, mesh_name, repr(e)))
                    _write(dict(arch=arch, shape=shape, mesh=mesh_name,
                                status="failed", error=repr(e)),
                           out_dir, arch, shape, mesh_name)
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:")
        for f in failures:
            print("   ", f)
        raise SystemExit(1)
    print("[dryrun] all requested cells passed")


if __name__ == "__main__":
    main()
