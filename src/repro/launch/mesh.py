"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_pdes_mesh(n_shards: int, *, multi_pod: bool = False):
    """Timeline-sharded mesh for the PDES engine: each device is one
    parallel timeline ('shards' axis = the paper's MPI ranks)."""
    if multi_pod:
        return jax.make_mesh((2, n_shards // 2), ("pod", "shards"))
    return jax.make_mesh((n_shards,), ("shards",))


def make_host_mesh(n: int, axes=("data", "model"), shape=None):
    """Small CPU mesh for tests (requires host_platform_device_count)."""
    if shape is None:
        shape = (n, 1)
    return jax.make_mesh(shape, axes)
