"""Serving launcher: batched decode with continuous batching.

``python -m repro.launch.serve --arch llama3.2-1b --smoke --requests 8``
"""
import argparse
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import registry as R
    from repro.models import api
    from repro.runtime.server import DecodeServer, Request

    cfg = R.get_config(args.arch)
    if args.smoke:
        cfg = R.smoke_config(cfg)
    params = api.init_params(cfg, jax.random.key(0))
    srv = DecodeServer(cfg, params, slots=args.slots, max_seq=args.max_seq)

    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        plen = int(rng.integers(4, 12))
        prompt = rng.integers(0, cfg.vocab_size, size=plen).tolist()
        srv.submit(Request(rid=rid, prompt=prompt,
                           max_new_tokens=args.max_new))
    t0 = time.perf_counter()
    done = srv.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {toks} tokens "
          f"in {dt:.2f}s ({toks / max(dt, 1e-9):.1f} tok/s)")
    for r in done[:4]:
        print(f"  rid={r.rid} out={r.output[:8]}...")
    return 0


if __name__ == "__main__":
    sys.exit(main())
