"""pixtral-12b [vlm] — mistral-nemo decoder; the pixtral-ViT frontend is a
STUB (input_specs supplies precomputed patch embeddings prepended to text).

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072
[hf:mistralai/Pixtral-12B-2409; unverified].  patch_tokens=256 is the stub
image budget per sequence.
"""
from repro.configs.base import ArchConfig, ATTN

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    block_pattern=(ATTN,),
    patch_tokens=256,
)
