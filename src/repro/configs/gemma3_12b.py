"""gemma3-12b [dense] — 5:1 local:global attention, 128k context.

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144
[hf:google/gemma-3-1b-pt; unverified].  head_dim=256 (gemma family),
GeGLU MLP, sliding window 1024 on local layers.
"""
from repro.configs.base import ArchConfig, ATTN, ATTN_LOCAL

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    block_pattern=(ATTN_LOCAL, ATTN_LOCAL, ATTN_LOCAL, ATTN_LOCAL,
                   ATTN_LOCAL, ATTN),
    mlp_kind="geglu",
    window=1024,
    rope_theta=1_000_000.0,
)
