"""whisper-tiny [audio] — encoder-decoder; conv frontend is a STUB
(input_specs supplies precomputed frame embeddings).

4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865 [arXiv:2212.04356;
unverified].  encoder_frames=1500 (30 s at 50 Hz after conv downsampling).
"""
from repro.configs.base import ArchConfig, ATTN

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    block_pattern=(ATTN,),
    encoder_layers=4,
    encoder_frames=1500,
)
