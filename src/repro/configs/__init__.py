from repro.configs.base import (  # noqa: F401
    ALL_SHAPES, ArchConfig, ShapeConfig,
    DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K,
)
from repro.configs.registry import (  # noqa: F401
    ARCHS, SHAPES, get_config, smoke_config,
)
