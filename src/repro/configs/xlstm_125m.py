"""xlstm-125m [ssm] — alternating mLSTM / sLSTM blocks.

12L d_model=768 4H (kv=4) d_ff=0 vocab=50304 [arXiv:2405.04517;
unverified].  d_ff=0: blocks carry their own projections, no separate MLP.
Recurrent state -> O(1) decode -> runs long_500k.
"""
from repro.configs.base import ArchConfig, MLSTM, SLSTM

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    block_pattern=(MLSTM, SLSTM),
    subquadratic=True,
)
