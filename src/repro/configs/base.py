"""Architecture config schema for the 10 assigned architectures.

One composable decoder/enc-dec substrate (repro.models) instantiates every
architecture from this dataclass; `block_pattern` is the repeating unit
scanned over depth (keeps HLO small so 512-device dry-run compiles stay
fast).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# block kinds usable in block_pattern
ATTN = "attn"                # global causal attention + MLP
ATTN_LOCAL = "attn_local"    # sliding-window attention + MLP
MOE = "moe"                  # attention + MoE FFN
MAMBA2 = "mamba2"            # Mamba2 SSM mixer
SLSTM = "slstm"              # xLSTM scalar-memory block
MLSTM = "mlstm"              # xLSTM matrix-memory block
SHARED_ATTN = "shared_attn"  # zamba2: one shared transformer block reused


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    block_pattern: Tuple[str, ...]    # repeats to cover n_layers
    mlp_kind: str = "swiglu"          # swiglu|geglu
    qkv_bias: bool = False
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    # SSM
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 256
    # attention details
    window: Optional[int] = None      # sliding-window size for attn_local
    rope_theta: float = 10_000.0
    # encoder-decoder (whisper): encoder layers + stub frame count
    encoder_layers: int = 0
    encoder_frames: int = 0
    # vlm (pixtral): stub patch-embedding prefix length
    patch_tokens: int = 0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    # whether a sub-quadratic long-context serve path exists (SSM/hybrid)
    subquadratic: bool = False
    # unroll the over-groups scan (used by the dry-run's R=1/R=2 depth
    # lowerings: XLA cost analysis visits a while body once, so roofline
    # deltas need straight-line HLO)
    unroll_groups: bool = False
    # flash-equivalent chunked attention (non-TPU lowering path)
    attn_chunk: int = 1024
    # §Perf optimization: statically skip fully-masked (q-block, kv-chunk)
    # pairs in causal attention (needs unroll_groups)
    attn_causal_skip: bool = False
    # §Perf optimization: slice MoE dispatch into per-data-shard segments
    # (local sort/scatter per slice, per-slice capacity) instead of one
    # global dispatch — removes the all-gathers a global argsort forces.
    # 0 = global dispatch (baseline).
    moe_dp_slices: int = 0
    # §Perf optimization v3: explicit expert parallelism via shard_map
    # (tokens replicated across 'model'; each shard runs its E/TP experts
    # locally; one psum combines) — removes GSPMD resharding guesswork.
    moe_shard_map: bool = False
    # §Perf optimization: keep the residual stream sequence-sharded over
    # 'model' THROUGH every block (Megatron-style SP) instead of only at
    # group boundaries — the MLP then never needs a seq gather and
    # attention gathers only K/V (kv_dim/d_model of the bytes).
    sp_residual: bool = False

    @property
    def pattern_repeats(self) -> int:
        assert self.n_layers % len(self.block_pattern) == 0, \
            (self.name, self.n_layers, self.block_pattern)
        return self.n_layers // len(self.block_pattern)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def scaled(self, **overrides) -> "ArchConfig":
        """Reduced copy for smoke tests."""
        return dataclasses.replace(self, **overrides)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell: training or serving geometry."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
