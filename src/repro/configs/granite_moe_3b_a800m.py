"""granite-moe-3b-a800m [moe] — 40 experts top-8.

32L d_model=1536 24H (GQA kv=8) d_ff=512 (per expert) vocab=49155
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].
"""
from repro.configs.base import ArchConfig, MOE

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    block_pattern=(MOE,),
    n_experts=40,
    moe_top_k=8,
)
