"""zamba2-2.7b [hybrid] — Mamba2 backbone + weight-shared attention block.

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf].  Pattern: 9 groups of (5x Mamba2 + 1 shared-attn);
the shared block's parameters are a single un-stacked set reused by every
group (Zamba2's weight sharing).  Sub-quadratic -> runs long_500k.
"""
from repro.configs.base import ArchConfig, MAMBA2, SHARED_ATTN

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    block_pattern=(MAMBA2, MAMBA2, MAMBA2, MAMBA2, MAMBA2, SHARED_ATTN),
    ssm_state=64,
    ssm_expand=2,
    subquadratic=True,
)
