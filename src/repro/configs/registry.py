"""Architecture registry: ``--arch <id>`` lookup + smoke-scale reduction."""
from __future__ import annotations

import dataclasses

from repro.configs import base as B
from repro.configs.gemma3_12b import CONFIG as GEMMA3_12B
from repro.configs.gemma_2b import CONFIG as GEMMA_2B
from repro.configs.granite_moe_3b_a800m import CONFIG as GRANITE_MOE
from repro.configs.llama3p2_1b import CONFIG as LLAMA32_1B
from repro.configs.moonshot_v1_16b_a3b import CONFIG as MOONSHOT
from repro.configs.pixtral_12b import CONFIG as PIXTRAL_12B
from repro.configs.qwen1p5_110b import CONFIG as QWEN15_110B
from repro.configs.whisper_tiny import CONFIG as WHISPER_TINY
from repro.configs.xlstm_125m import CONFIG as XLSTM_125M
from repro.configs.zamba2_2p7b import CONFIG as ZAMBA2_27B

ARCHS = {c.name: c for c in (
    ZAMBA2_27B, GEMMA3_12B, QWEN15_110B, LLAMA32_1B, GEMMA_2B,
    XLSTM_125M, MOONSHOT, GRANITE_MOE, WHISPER_TINY, PIXTRAL_12B,
)}

SHAPES = {s.name: s for s in B.ALL_SHAPES}


def get_config(name: str) -> B.ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(cfg: B.ArchConfig) -> B.ArchConfig:
    """Reduced same-family config: small width/depth/vocab, few experts —
    runs one train/forward step on CPU in the per-arch smoke tests."""
    n_heads = min(cfg.n_heads, 4)
    ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    n_kv = max(1, n_heads // ratio)
    upd = dict(
        n_layers=len(cfg.block_pattern),
        d_model=128,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=32,
        d_ff=0 if cfg.d_ff == 0 else 256,
        vocab_size=512,
    )
    if cfg.n_experts:
        upd.update(n_experts=8, moe_top_k=min(cfg.moe_top_k, 2), d_ff=64)
    if cfg.ssm_state:
        upd.update(ssm_state=16, ssm_chunk=16)
    if cfg.window:
        upd.update(window=16)
    if cfg.encoder_layers:
        upd.update(encoder_layers=2, encoder_frames=24)
    if cfg.patch_tokens:
        upd.update(patch_tokens=8)
    return dataclasses.replace(cfg, **upd)


SMOKE_SHAPE_TRAIN = B.ShapeConfig("smoke_train", seq_len=64, global_batch=2,
                                  kind="train")
SMOKE_SHAPE_DECODE = B.ShapeConfig("smoke_decode", seq_len=64,
                                   global_batch=2, kind="decode")
