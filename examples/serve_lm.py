"""Serve a small LM with batched requests + continuous batching.

    PYTHONPATH=src python examples/serve_lm.py --requests 12
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import registry as R
from repro.models import api
from repro.runtime.server import DecodeServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = R.smoke_config(R.get_config(args.arch))
    params = api.init_params(cfg, jax.random.key(0))
    srv = DecodeServer(cfg, params, slots=args.slots, max_seq=128)

    rng = np.random.default_rng(7)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=int(rng.integers(4, 16))).tolist()
        srv.submit(Request(rid=rid, prompt=prompt,
                           max_new_tokens=args.max_new))

    t0 = time.perf_counter()
    done = srv.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests / {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s, {args.slots} slots, "
          f"continuous batching)")
    for r in sorted(done, key=lambda r: r.rid)[:5]:
        print(f"  rid={r.rid:2d} prompt[:4]={r.prompt[:4]} "
              f"-> out[:8]={r.output[:8]}")


if __name__ == "__main__":
    main()
