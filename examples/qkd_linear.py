"""Linear-topology QKD strong scaling — the paper's §III-B experiment,
end to end: simulate, decompose per-process time, print the scaling table.

    PYTHONPATH=src python examples/qkd_linear.py [--routers 256]
"""
import argparse

from repro.core import (
    EngineConfig, FRONTIER, Simulator, breakdown, linear_network,
    make_partition,
)
from repro.core.costmodel import SEQUENCE_PY


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--routers", type=int, default=256)
    ap.add_argument("--photons", type=int, default=32)
    args = ap.parse_args()

    net = linear_network(n_routers=args.routers, n_photons=args.photons,
                         period_ns=4_000, hop_delay_ns=25_000, loss_p=0.1)
    print(f"{args.routers} routers, {len(net.sessions)} QKD sessions")
    print("S,compute_s,socket_s,mpi_s,total_s,speedup")
    base = None
    for S in (1, 2, 4, 8, 16):
        part = make_partition(net, S, scheme="contiguous")
        cfg = EngineConfig(n_shards=S, pool_cap=max(65_536 // S, 2_048),
                           qsm_cap=max(8_192 // S, 128),
                           outbox_cap=max(8_192 // S, 256),
                           route_cap=max(8_192 // S, 256))
        res = Simulator(net, part, cfg).run()
        bd = breakdown(res.metrics, S, FRONTIER, SEQUENCE_PY)
        av = bd.averages()
        total = bd.total_wall
        base = base or total
        print(f"{S},{av['compute']:.3f},{av['qsm']:.3f},"
              f"{av['wait'] + av['comm']:.3f},{total:.3f},"
              f"{base / total:.2f}")


if __name__ == "__main__":
    main()
