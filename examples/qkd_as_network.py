"""End-to-end driver: the paper's autonomous-system experiment with the
full analysis pipeline — partition, simulate, barrier-split decomposition
(Fig 5), straggler identification (Fig 7), and the beyond-paper fix
(work stealing) applied and verified bit-identical.

    PYTHONPATH=src python examples/qkd_as_network.py [--routers 256]
"""
import argparse

import numpy as np

from repro.core import (
    EngineConfig, FRONTIER, Simulator, as_network, breakdown,
    cut_channels, load_imbalance, make_partition,
)
from repro.core.costmodel import SEQUENCE_PY


def engine_cfg(S):
    return EngineConfig(n_shards=S, pool_cap=max(131_072 // S, 2_048),
                        qsm_cap=max(16_384 // S, 128),
                        outbox_cap=max(16_384 // S, 256),
                        route_cap=max(16_384 // S, 256))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--routers", type=int, default=256)
    ap.add_argument("--shards", type=int, default=8)
    args = ap.parse_args()
    S = args.shards

    net = as_network(n_routers=args.routers, n_as=max(args.routers // 32, 4),
                     n_photons=32, period_ns=8_000, seed=0)
    part = make_partition(net, S, scheme="sa")
    print(f"AS network: {args.routers} routers, {len(net.sessions)} "
          f"sessions; SA partition cut={cut_channels(net, part)} "
          f"predicted-load imbalance={load_imbalance(net, part, S):.2f}")

    # --- static partition (paper's setting) ---
    res = Simulator(net, part, engine_cfg(S)).run()
    bd = breakdown(res.metrics, S, FRONTIER, SEQUENCE_PY)
    av = bd.averages()
    print("\n[static] barrier-split decomposition (Fig 5 methodology):")
    print(f"  compute {av['compute']:.3f}s | WAIT {av['wait']:.3f}s | "
          f"comm {av['comm']:.5f}s | qsm {av['qsm']:.3f}s")
    per_proc = bd.compute.sum(axis=1)
    print(f"  per-process compute (Fig 7): {np.round(per_proc, 2).tolist()}")
    print(f"  straggler dominance: {per_proc.max() / np.median(per_proc):.2f}x"
          f" the median process")

    # --- work stealing (the paper's §IV proposal, built) ---
    res2 = Simulator(net, part, engine_cfg(S)).run(steal_every=2,
                                                   steal_threshold=1.1)
    assert res.fingerprint() == res2.fingerprint(), "results must not change"
    bd2 = breakdown(res2.metrics, S, FRONTIER, SEQUENCE_PY)
    print(f"\n[stealing] {len(res2.steals)} rebalance rounds, results "
          f"bit-identical (fingerprint {res2.fingerprint():#x})")
    print(f"  projected total: {bd.total_wall:.3f}s -> "
          f"{bd2.total_wall:.3f}s "
          f"({bd.total_wall / bd2.total_wall:.2f}x)")


if __name__ == "__main__":
    main()
