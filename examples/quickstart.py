"""Quickstart: simulate a small QKD network on 4 parallel timelines.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (
    EngineConfig, Simulator, linear_network, make_partition,
)


def main():
    # 16 routers in a chain, one BB84 session per adjacent pair
    net = linear_network(n_routers=16, n_photons=200, period_ns=2_000,
                         hop_delay_ns=25_000, loss_p=0.15)

    # partition routers across 4 parallel timelines (the paper's "processes")
    part = make_partition(net, 4, scheme="sa")

    cfg = EngineConfig(n_shards=4, pool_cap=8_192, qsm_cap=2_048,
                       outbox_cap=2_048, route_cap=512)
    sim = Simulator(net, part, cfg)
    res = sim.run()

    print(f"epochs run          : {res.n_epochs}")
    print(f"photons emitted     : {res.emitted.sum()}")
    print(f"photons detected    : {res.detected.sum()} "
          f"({res.detected.sum() / res.emitted.sum():.1%})")
    print(f"sifted key bits     : {res.sifted.sum()} "
          f"(~50% of detected, BB84 basis match)")
    print(f"QBER                : {res.qber:.4f} (0 = noiseless channel)")
    print(f"per-session keys    : {res.sifted.tolist()}")
    print(f"result fingerprint  : {res.fingerprint():#x} "
          f"(identical for ANY shard count)")
    assert res.overflow == 0 and res.stale_reads == 0


if __name__ == "__main__":
    main()
