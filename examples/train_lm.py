"""End-to-end LM training with checkpoint/resume on the llama3.2 family.

Default is CPU-sized (~7M params, 200 steps, loss visibly descends);
``--full`` trains a ~100M-param llama3.2-style config (same code path,
sized for a real accelerator).

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--full]
"""
import argparse
import dataclasses
import tempfile

from repro.configs import registry as R
from repro.runtime.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="~100M-param config (accelerator-sized)")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    base = R.get_config("llama3.2-1b")
    if args.full:
        cfg = dataclasses.replace(
            base, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            head_dim=64, d_ff=2048, vocab_size=32_000)
        seq, batch = 512, 8
    else:
        cfg = dataclasses.replace(
            base, n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
            head_dim=64, d_ff=512, vocab_size=2_048)
        seq, batch = 128, 8

    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    tc = TrainConfig(arch=cfg, steps=args.steps, lr=1e-3, seq_len=seq,
                     global_batch=batch, ckpt_dir=ckpt, ckpt_every=50)
    tr = Trainer(tc)
    n_params = sum(x.size for x in __import__("jax").tree.leaves(tr.params))
    print(f"arch={cfg.name} params={n_params / 1e6:.1f}M "
          f"seq={seq} batch={batch} ckpt={ckpt}")
    summary = tr.train()
    first = tr.timer.records[0].loss
    print(f"loss: {first:.3f} -> {summary['final_loss']:.3f} over "
          f"{summary['steps']} steps "
          f"({summary['mean_step_s'] * 1e3:.0f} ms/step)")
    print("summary:", summary)
    assert summary["final_loss"] < first


if __name__ == "__main__":
    main()
